//! Chord overlay (Stoica et al., SIGCOMM'01) — baseline #1 (paper §V-A1).
//!
//! Chord hashes nodes onto a logical identifier ring and adds finger
//! edges at power-of-two identifier distances. The hash is latency-
//! oblivious, so the logical ring is a *random* ring physically — which
//! is exactly the inefficiency DGRO's ring selection repairs by swapping
//! the logical ring for the shortest ring (Fig 5).

use crate::graph::ring::Ring;
use crate::graph::Graph;
use crate::latency::LatencyMatrix;
use crate::util::rng::Rng;

/// A Chord overlay: the successor ring (in hash order) + finger tables.
#[derive(Clone, Debug)]
pub struct Chord {
    /// Nodes in identifier order (successor ring).
    pub ring: Ring,
    /// Finger edges (u, v) in node ids, deduplicated.
    pub fingers: Vec<(u32, u32)>,
}

impl Chord {
    /// Build a Chord overlay. The identifier assignment is a random
    /// permutation (consistent hashing). Fingers connect each node to the
    /// node 2^i positions ahead on the identifier ring, i = 1..log2(N).
    pub fn build(n: usize, rng: &mut Rng) -> Chord {
        let order = rng.permutation(n);
        Chord::from_order(order)
    }

    /// Build with an explicit identifier ring (used by the DGRO swap:
    /// same finger structure, different base ring).
    pub fn from_order(order: Vec<u32>) -> Chord {
        let n = order.len();
        let ring = Ring::new(order).expect("valid identifier ring");
        let order = ring.order();
        let mut fingers = Vec::new();
        let bits = (n as f64).log2().floor() as usize;
        for pos in 0..n {
            for i in 1..=bits {
                let step = 1usize << i;
                if step >= n {
                    break;
                }
                let tgt = (pos + step) % n;
                let (u, v) = (order[pos], order[tgt]);
                if u != v {
                    fingers.push((u.min(v), u.max(v)));
                }
            }
        }
        fingers.sort_unstable();
        fingers.dedup();
        Chord { ring, fingers }
    }

    /// The overlay graph: successor ring + fingers, physical weights.
    pub fn to_graph(&self, w: &LatencyMatrix) -> Graph {
        let mut g = self.ring.to_graph(w);
        for &(u, v) in &self.fingers {
            g.add_edge(u as usize, v as usize, w.get(u as usize, v as usize));
        }
        g
    }

    /// DGRO's repair (Fig 5): keep the finger structure, replace the
    /// identifier ring with the provided (e.g. shortest) ring.
    pub fn with_base_ring(&self, ring: Ring) -> Chord {
        Chord::from_order(ring.order().to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{components, diameter};
    use crate::latency::synthetic;
    use crate::topology::shortest_ring;

    #[test]
    fn chord_structure() {
        let mut rng = Rng::new(1);
        let c = Chord::build(32, &mut rng);
        c.ring.validate().unwrap();
        // log2(32) = 5 -> finger steps 2,4,8,16 exist.
        assert!(!c.fingers.is_empty());
        let w = synthetic::uniform(32, &mut rng);
        let g = c.to_graph(&w);
        assert!(components::is_connected(&g));
        // Degree bounded by 2 (ring) + 2 * fingers-per-node.
        assert!(g.max_degree() <= 2 + 2 * 5);
    }

    #[test]
    fn logical_hop_count_logarithmic() {
        // Chord's raison d'être: unit-weight overlay has O(log N) diameter.
        let mut rng = Rng::new(2);
        let c = Chord::build(64, &mut rng);
        let unit = LatencyMatrix::from_fn(64, |_, _| 1.0);
        let g = c.to_graph(&unit);
        let d = diameter::diameter(&g);
        assert!(d <= 7.0, "logical diameter {d} too high for N=64");
    }

    #[test]
    fn swap_base_ring_keeps_connectivity() {
        let mut rng = Rng::new(3);
        let w = synthetic::uniform(40, &mut rng);
        let c = Chord::build(40, &mut rng);
        let swapped = c.with_base_ring(shortest_ring(&w, 0));
        let g = swapped.to_graph(&w);
        assert!(components::is_connected(&g));
        assert_eq!(swapped.ring.order()[0], 0);
    }

    #[test]
    fn fingers_deduplicated() {
        let mut rng = Rng::new(4);
        let c = Chord::build(16, &mut rng);
        let mut f = c.fingers.clone();
        f.dedup();
        assert_eq!(f.len(), c.fingers.len());
        assert!(c.fingers.iter().all(|&(u, v)| u < v));
    }
}
