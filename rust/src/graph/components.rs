//! Connected components (BFS over adjacency, or via a distance matrix).

use super::apsp::{DistMatrix, INF};
use super::Graph;

/// Component label per node (labels are 0..k in first-seen order).
pub fn components(g: &Graph) -> Vec<u32> {
    let n = g.n();
    let mut label = vec![u32::MAX; n];
    let mut next = 0u32;
    let mut queue = std::collections::VecDeque::new();
    for s in 0..n {
        if label[s] != u32::MAX {
            continue;
        }
        label[s] = next;
        queue.push_back(s);
        while let Some(u) = queue.pop_front() {
            for &(v, _) in g.neighbors(u) {
                let v = v as usize;
                if label[v] == u32::MAX {
                    label[v] = next;
                    queue.push_back(v);
                }
            }
        }
        next += 1;
    }
    label
}

/// Component labels derived from an APSP matrix (finite distance ⇔ same
/// component).
pub fn components_from_dist(dm: &DistMatrix) -> Vec<u32> {
    let n = dm.n;
    let mut label = vec![u32::MAX; n];
    let mut next = 0u32;
    for s in 0..n {
        if label[s] != u32::MAX {
            continue;
        }
        for v in 0..n {
            if dm.get(s, v) != INF {
                label[v] = next;
            }
        }
        next += 1;
    }
    label
}

/// Members of the largest component (ties break toward the lower label).
pub fn largest(labels: &[u32]) -> Vec<u32> {
    if labels.is_empty() {
        return Vec::new();
    }
    let k = (*labels.iter().max().unwrap() + 1) as usize;
    let mut counts = vec![0usize; k];
    for &l in labels {
        counts[l as usize] += 1;
    }
    let best = counts
        .iter()
        .enumerate()
        .max_by_key(|&(i, &c)| (c, usize::MAX - i))
        .unwrap()
        .0 as u32;
    labels
        .iter()
        .enumerate()
        .filter(|&(_, &l)| l == best)
        .map(|(i, _)| i as u32)
        .collect()
}

/// True iff the whole graph is one component.
pub fn is_connected(g: &Graph) -> bool {
    if g.n() == 0 {
        return true;
    }
    let labels = components(g);
    labels.iter().all(|&l| l == 0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::apsp;

    #[test]
    fn labels_split_components() {
        let g = Graph::from_weighted_edges(
            5,
            &[(0, 1, 1.0), (1, 2, 1.0), (3, 4, 1.0)],
        );
        let l = components(&g);
        assert_eq!(l[0], l[1]);
        assert_eq!(l[1], l[2]);
        assert_eq!(l[3], l[4]);
        assert_ne!(l[0], l[3]);
    }

    #[test]
    fn dist_labels_match_bfs_labels() {
        let g = Graph::from_weighted_edges(
            6,
            &[(0, 1, 1.0), (2, 3, 1.0), (3, 4, 1.0)],
        );
        let a = components(&g);
        let dm = apsp::apsp(&g);
        let b = components_from_dist(&dm);
        for i in 0..6 {
            for j in 0..6 {
                assert_eq!(a[i] == a[j], b[i] == b[j], "({i},{j})");
            }
        }
    }

    #[test]
    fn largest_picks_biggest() {
        let labels = vec![0, 0, 1, 1, 1, 2];
        assert_eq!(largest(&labels), vec![2, 3, 4]);
    }

    #[test]
    fn connectivity() {
        let mut g = Graph::empty(3);
        g.add_edge(0, 1, 1.0);
        assert!(!is_connected(&g));
        g.add_edge(1, 2, 1.0);
        assert!(is_connected(&g));
        assert!(is_connected(&Graph::empty(0)));
    }
}
