//! Diameter and eccentricity — the paper's performance metric (Eqn 1):
//! D(G) = max_{u,v} d(u, v), over the largest connected component when
//! the graph is disconnected (paper §IV-C convention).
//!
//! Serial algorithms live here; [`super::eval::EvalPool`] provides the
//! parallel counterparts (`diameter_par`, warm-started
//! `diameter_with_seeds`, population-wide `diameter_batch`) that return
//! the same values with the SSSP sweeps spread across threads.

use super::apsp::{self, DistMatrix, INF};
use super::components;
use super::Graph;

/// Exact diameter of `g` (largest component).
///
/// Uses the Takes–Kosters eccentricity-bounding algorithm
/// ("BoundingDiameters"): run SSSP from strategically chosen nodes,
/// maintain per-node eccentricity bounds
///   eccL[u] = max(eccL[u], ecc(v) − d(v,u), d(v,u))
///   eccU[u] = min(eccU[u], ecc(v) + d(v,u))
/// and drop u once eccU[u] ≤ lb (it cannot raise the diameter). On the
/// small-world K-ring overlays the paper studies this converges in a
/// handful of SSSPs instead of N — the single biggest L3 speedup
/// (EXPERIMENTS.md §Perf, L3 iteration 5). Exactness is asserted against
/// the APSP oracle by unit + property tests.
pub fn diameter(g: &Graph) -> f32 {
    let n = g.n();
    if n == 0 || g.m() == 0 {
        return 0.0;
    }
    let members = components::largest(&components::components(g));
    if members.len() < 2 {
        return 0.0;
    }
    let csr = apsp::Csr::build(g);
    let mut dist = vec![apsp::INF; n];
    let mut heap = std::collections::BinaryHeap::with_capacity(n);

    let mut ecc_lo = vec![0.0f32; n];
    let mut ecc_hi = vec![f32::INFINITY; n];
    let mut cand: Vec<u32> = members.clone();
    let mut lb = 0.0f32;
    let mut pick_hi = true; // interleave: max-upper / max-lower picks

    while !cand.is_empty() {
        // Selection heuristic: alternately the candidate with the
        // largest upper bound (can certify the diameter) and the one
        // with the largest lower bound (a far-out node tightens bounds
        // fastest).
        let (idx, _) = cand
            .iter()
            .enumerate()
            .map(|(i, &u)| {
                let score = if pick_hi {
                    ecc_hi[u as usize]
                } else {
                    ecc_lo[u as usize]
                };
                (i, score)
            })
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        pick_hi = !pick_hi;
        let v = cand.swap_remove(idx) as usize;

        csr.dijkstra_scratch(v, &mut dist, &mut heap);
        let mut ecc_v = 0.0f32;
        for &u in &members {
            let d = dist[u as usize];
            if d.is_finite() && d > ecc_v {
                ecc_v = d;
            }
        }
        if ecc_v > lb {
            lb = ecc_v;
        }
        // Tighten bounds and prune.
        cand.retain(|&u| {
            let u = u as usize;
            let d = dist[u];
            if d.is_finite() {
                let lo = (ecc_v - d).max(d);
                if lo > ecc_lo[u] {
                    ecc_lo[u] = lo;
                }
                let hi = ecc_v + d;
                if hi < ecc_hi[u] {
                    ecc_hi[u] = hi;
                }
            }
            if ecc_lo[u] > lb {
                lb = ecc_lo[u];
            }
            ecc_hi[u] > lb + 1e-6 // keep only if it could raise the max
        });
    }
    lb
}

/// Exact diameter via full APSP — the O(N·E·logN) oracle the bounding
/// algorithm is validated against (and the right call when the caller
/// needs the distance matrix anyway).
pub fn diameter_apsp(g: &Graph) -> f32 {
    let dm = apsp::apsp(g);
    diameter_of_dist(&dm)
}

/// Diameter given a precomputed APSP matrix (largest component).
pub fn diameter_of_dist(dm: &DistMatrix) -> f32 {
    let comp = components::components_from_dist(dm);
    let largest = components::largest(&comp);
    let mut best = 0.0f32;
    for &u in &largest {
        for &v in &largest {
            let d = dm.get(u as usize, v as usize);
            if d != INF && d > best {
                best = d;
            }
        }
    }
    best
}

/// Eccentricity of every node: the max finite distance from it. A node
/// with no finite distance to any *other* node (isolated in a multi-node
/// graph) gets `INF` — it has no farthest peer, and reporting `0.0`
/// would make it look central. In a single-node graph the eccentricity
/// is `0.0` (the node is its whole component).
pub fn eccentricities(dm: &DistMatrix) -> Vec<f32> {
    let n = dm.n;
    (0..n)
        .map(|u| {
            let mut e = 0.0f32;
            let mut reaches_any = n == 1;
            for v in 0..n {
                if v == u {
                    continue;
                }
                let d = dm.get(u, v);
                if d != INF {
                    reaches_any = true;
                    if d > e {
                        e = d;
                    }
                }
            }
            if reaches_any {
                e
            } else {
                INF
            }
        })
        .collect()
}

/// Average pairwise latency over connected pairs (used by the adaptive
/// ring selection's global statistics and several figure harnesses).
pub fn mean_pairwise(dm: &DistMatrix) -> f32 {
    let n = dm.n;
    let mut sum = 0.0f64;
    let mut cnt = 0usize;
    for u in 0..n {
        for v in 0..n {
            if u == v {
                continue;
            }
            let d = dm.get(u, v);
            if d != INF {
                sum += d as f64;
                cnt += 1;
            }
        }
    }
    if cnt == 0 {
        0.0
    } else {
        (sum / cnt as f64) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_diameter() {
        // Unit-weight 6-ring: diameter 3.
        let mut g = Graph::empty(6);
        for i in 0..6 {
            g.add_edge(i, (i + 1) % 6, 1.0);
        }
        assert_eq!(diameter(&g), 3.0);
    }

    #[test]
    fn weighted_path_diameter() {
        let g = Graph::from_weighted_edges(
            3,
            &[(0, 1, 2.5), (1, 2, 4.0)],
        );
        assert_eq!(diameter(&g), 6.5);
    }

    #[test]
    fn disconnected_uses_largest_component() {
        // Component A: path of 3 nodes (diam 2), component B: edge w=50.
        let g = Graph::from_weighted_edges(
            5,
            &[(0, 1, 1.0), (1, 2, 1.0), (3, 4, 50.0)],
        );
        assert_eq!(diameter(&g), 2.0);
    }

    #[test]
    fn empty_graph_diameter_zero() {
        let g = Graph::empty(4);
        assert_eq!(diameter(&g), 0.0);
    }

    #[test]
    fn eccentricities_of_path() {
        let g = Graph::from_weighted_edges(3, &[(0, 1, 1.0), (1, 2, 1.0)]);
        let dm = apsp::apsp(&g);
        assert_eq!(eccentricities(&dm), vec![2.0, 1.0, 2.0]);
    }

    #[test]
    fn eccentricity_of_isolated_node_is_inf() {
        // Node 3 has no edges: doc contract says INF, not 0.
        let g = Graph::from_weighted_edges(4, &[(0, 1, 1.0), (1, 2, 1.0)]);
        let dm = apsp::apsp(&g);
        assert_eq!(eccentricities(&dm), vec![2.0, 1.0, 2.0, INF]);
        // A single-node graph is its own component: eccentricity 0.
        let dm1 = apsp::apsp(&Graph::empty(1));
        assert_eq!(eccentricities(&dm1), vec![0.0]);
    }

    #[test]
    fn bounding_diameter_matches_apsp_oracle() {
        use crate::latency::Model;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xD1A);
        for trial in 0..20 {
            let n = 10 + 13 * (trial % 7);
            let model = Model::ALL[trial % 4];
            let w = model.sample(n, &mut rng);
            let k = crate::topology::paper_k(n);
            let g = crate::topology::kring::random_krings(n, k, &mut rng)
                .to_graph(&w);
            let fast = diameter(&g);
            let slow = diameter_apsp(&g);
            assert!(
                (fast - slow).abs() <= 1e-3 * slow.max(1.0),
                "trial {trial}: bounding {fast} vs apsp {slow}"
            );
        }
    }

    #[test]
    fn bounding_diameter_handles_disconnected() {
        let g = Graph::from_weighted_edges(
            6,
            &[(0, 1, 1.0), (1, 2, 1.0), (3, 4, 9.0)],
        );
        assert_eq!(diameter(&g), diameter_apsp(&g));
    }

    #[test]
    fn mean_pairwise_simple() {
        let g = Graph::from_weighted_edges(3, &[(0, 1, 1.0), (1, 2, 1.0)]);
        let dm = apsp::apsp(&g);
        // pairs: (0,1)=1, (0,2)=2, (1,2)=1 both directions -> mean 4/3.
        assert!((mean_pairwise(&dm) - 4.0 / 3.0).abs() < 1e-6);
    }
}
