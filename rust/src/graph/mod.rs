//! Weighted-graph substrate: topology representation, shortest paths,
//! diameter — the metric every DGRO experiment is scored on (paper §III).
//! [`eval`] parallelizes the whole layer: [`eval::EvalPool`] runs APSP /
//! diameter / candidate-batch evaluation across threads with recycled
//! scratch, exactly matching the serial algorithms here.

pub mod apsp;
pub mod components;
pub mod diameter;
pub mod eval;
pub mod ring;

use std::collections::HashSet;

/// An undirected weighted overlay graph in adjacency-list form.
///
/// Nodes are `0..n`. Edges are stored once per endpoint (symmetric). The
/// builders in `topology/` produce graphs via [`Graph::from_edges`] with
/// weights looked up in a latency matrix.
#[derive(Clone, Debug)]
pub struct Graph {
    n: usize,
    adj: Vec<Vec<(u32, f32)>>,
    m: usize,
}

impl Graph {
    /// An edgeless graph on `n` nodes.
    pub fn empty(n: usize) -> Graph {
        Graph {
            n,
            adj: vec![Vec::new(); n],
            m: 0,
        }
    }

    /// Build from an undirected edge list with explicit weights.
    /// Duplicate edges keep the smaller weight (parallel links collapse).
    pub fn from_weighted_edges(
        n: usize,
        edges: &[(u32, u32, f32)],
    ) -> Graph {
        let mut g = Graph::empty(n);
        for &(u, v, w) in edges {
            g.add_edge(u as usize, v as usize, w);
        }
        g
    }

    /// Build from an edge list, weights from a latency matrix accessor.
    pub fn from_edges(
        n: usize,
        edges: &[(u32, u32)],
        weight: impl Fn(usize, usize) -> f32,
    ) -> Graph {
        let mut g = Graph::empty(n);
        for &(u, v) in edges {
            g.add_edge(u as usize, v as usize, weight(u as usize, v as usize));
        }
        g
    }

    /// Add an undirected edge; ignores self-loops; duplicate edges keep
    /// the minimum weight.
    pub fn add_edge(&mut self, u: usize, v: usize, w: f32) {
        assert!(u < self.n && v < self.n, "edge ({u},{v}) out of range");
        if u == v {
            return;
        }
        if let Some(slot) =
            self.adj[u].iter_mut().find(|(x, _)| *x as usize == v)
        {
            if w < slot.1 {
                slot.1 = w;
                self.adj[v]
                    .iter_mut()
                    .find(|(x, _)| *x as usize == u)
                    .expect("symmetric edge")
                    .1 = w;
            }
            return;
        }
        self.adj[u].push((v as u32, w));
        self.adj[v].push((u as u32, w));
        self.m += 1;
    }

    /// Number of nodes.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of undirected edges.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Adjacency list of `u` as (neighbor, weight) pairs.
    pub fn neighbors(&self, u: usize) -> &[(u32, f32)] {
        &self.adj[u]
    }

    /// Number of incident edges of `u`.
    pub fn degree(&self, u: usize) -> usize {
        self.adj[u].len()
    }

    /// Largest degree over all nodes.
    pub fn max_degree(&self) -> usize {
        (0..self.n).map(|u| self.degree(u)).max().unwrap_or(0)
    }

    /// Whether the undirected edge (u, v) exists.
    pub fn has_edge(&self, u: usize, v: usize) -> bool {
        self.adj[u].iter().any(|(x, _)| *x as usize == v)
    }

    /// Undirected edge list (u < v), for serialization and merging.
    pub fn edges(&self) -> Vec<(u32, u32, f32)> {
        let mut out = Vec::with_capacity(self.m);
        for u in 0..self.n {
            for &(v, w) in &self.adj[u] {
                if (u as u32) < v {
                    out.push((u as u32, v, w));
                }
            }
        }
        out
    }

    /// Union of this graph's edges with another's (same node set); keeps
    /// minimum weight on duplicates. This is how K-ring overlays compose.
    pub fn union(&self, other: &Graph) -> Graph {
        assert_eq!(self.n, other.n, "union over different node sets");
        let mut g = self.clone();
        for (u, v, w) in other.edges() {
            g.add_edge(u as usize, v as usize, w);
        }
        g
    }

    /// Structural equality on edge sets (ignores adjacency order).
    pub fn same_edges(&self, other: &Graph) -> bool {
        if self.n != other.n || self.m != other.m {
            return false;
        }
        let a: HashSet<(u32, u32)> = self
            .edges()
            .iter()
            .map(|&(u, v, _)| (u, v))
            .collect();
        other.edges().iter().all(|&(u, v, _)| a.contains(&(u, v)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_query() {
        let g = Graph::from_weighted_edges(
            4,
            &[(0, 1, 1.0), (1, 2, 2.0), (2, 3, 3.0)],
        );
        assert_eq!(g.n(), 4);
        assert_eq!(g.m(), 3);
        assert!(g.has_edge(0, 1));
        assert!(g.has_edge(1, 0));
        assert!(!g.has_edge(0, 2));
        assert_eq!(g.degree(1), 2);
        assert_eq!(g.max_degree(), 2);
    }

    #[test]
    fn self_loops_ignored() {
        let mut g = Graph::empty(3);
        g.add_edge(1, 1, 5.0);
        assert_eq!(g.m(), 0);
    }

    #[test]
    fn duplicate_edge_keeps_min_weight() {
        let mut g = Graph::empty(3);
        g.add_edge(0, 1, 5.0);
        g.add_edge(1, 0, 2.0);
        assert_eq!(g.m(), 1);
        assert_eq!(g.neighbors(0)[0].1, 2.0);
        assert_eq!(g.neighbors(1)[0].1, 2.0);
    }

    #[test]
    fn edges_listed_once() {
        let g = Graph::from_weighted_edges(
            3,
            &[(0, 1, 1.0), (1, 2, 1.0), (0, 2, 1.0)],
        );
        let es = g.edges();
        assert_eq!(es.len(), 3);
        assert!(es.iter().all(|&(u, v, _)| u < v));
    }

    #[test]
    fn union_composes_and_keeps_min() {
        let a = Graph::from_weighted_edges(3, &[(0, 1, 3.0)]);
        let b = Graph::from_weighted_edges(3, &[(0, 1, 1.0), (1, 2, 2.0)]);
        let u = a.union(&b);
        assert_eq!(u.m(), 2);
        assert_eq!(u.neighbors(0)[0].1, 1.0);
    }

    #[test]
    fn same_edges_ignores_order() {
        let a = Graph::from_weighted_edges(3, &[(0, 1, 1.0), (1, 2, 1.0)]);
        let b = Graph::from_weighted_edges(3, &[(1, 2, 1.0), (0, 1, 1.0)]);
        assert!(a.same_edges(&b));
        let c = Graph::from_weighted_edges(3, &[(0, 2, 1.0), (0, 1, 1.0)]);
        assert!(!a.same_edges(&c));
    }
}
