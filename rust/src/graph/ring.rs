//! Ring representation — the unit of topology the whole paper optimizes.
//!
//! A [`Ring`] is a Hamiltonian cycle over nodes `0..n`, stored as a visit
//! order. The invariants (`validate`) are enforced by proptests: a valid
//! ring is a permutation of 0..n, every node has degree exactly 2 in the
//! induced graph, and the induced graph is connected.

use anyhow::{bail, Result};

use super::Graph;
use crate::latency::LatencyMatrix;

/// A ring topology: `order[i]` is connected to `order[i+1]` (wrapping).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Ring {
    order: Vec<u32>,
}

impl Ring {
    /// Construct from a visit order; validates it is a permutation.
    pub fn new(order: Vec<u32>) -> Result<Ring> {
        let n = order.len();
        if n < 3 {
            bail!("a ring needs >= 3 nodes, got {n}");
        }
        let mut seen = vec![false; n];
        for &v in &order {
            let v = v as usize;
            if v >= n {
                bail!("node {v} out of range (n = {n})");
            }
            if seen[v] {
                bail!("node {v} appears twice");
            }
            seen[v] = true;
        }
        Ok(Ring { order })
    }

    /// The identity ring 0 -> 1 -> ... -> n-1 -> 0.
    pub fn identity(n: usize) -> Ring {
        Ring {
            order: (0..n as u32).collect(),
        }
    }

    /// Number of nodes on the ring.
    pub fn n(&self) -> usize {
        self.order.len()
    }

    /// The visit order (a permutation of 0..n).
    pub fn order(&self) -> &[u32] {
        &self.order
    }

    /// Ring edges (consecutive pairs + closing edge).
    pub fn edges(&self) -> Vec<(u32, u32)> {
        let n = self.order.len();
        (0..n)
            .map(|i| (self.order[i], self.order[(i + 1) % n]))
            .collect()
    }

    /// Induced graph with weights from a latency matrix.
    pub fn to_graph(&self, w: &LatencyMatrix) -> Graph {
        Graph::from_edges(self.n(), &self.edges(), |u, v| w.get(u, v))
    }

    /// Total circumference (sum of ring-edge latencies) — the TSP-style
    /// objective, reported alongside diameter in the ablations.
    pub fn length(&self, w: &LatencyMatrix) -> f32 {
        self.edges()
            .iter()
            .map(|&(u, v)| w.get(u as usize, v as usize))
            .sum()
    }

    /// Check every structural invariant; used by proptests and debug
    /// assertions in the builders.
    pub fn validate(&self) -> Result<()> {
        let _ = Ring::new(self.order.clone())?;
        Ok(())
    }

    /// Canonical form: rotated so node 0 is first, direction chosen so the
    /// second element is the smaller neighbor. Two rings with identical
    /// edge sets compare equal in canonical form.
    pub fn canonical(&self) -> Ring {
        let n = self.order.len();
        let zero_pos = self
            .order
            .iter()
            .position(|&v| v == 0)
            .expect("validated ring contains 0");
        let mut fwd: Vec<u32> = Vec::with_capacity(n);
        for i in 0..n {
            fwd.push(self.order[(zero_pos + i) % n]);
        }
        let mut bwd: Vec<u32> = Vec::with_capacity(n);
        for i in 0..n {
            bwd.push(self.order[(zero_pos + n - i) % n]);
        }
        if fwd[1] <= bwd[1] {
            Ring { order: fwd }
        } else {
            Ring { order: bwd }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::latency::LatencyMatrix;

    fn unit_latency(n: usize) -> LatencyMatrix {
        LatencyMatrix::from_fn(n, |u, v| if u == v { 0.0 } else { 1.0 })
    }

    #[test]
    fn identity_ring_edges() {
        let r = Ring::identity(4);
        assert_eq!(r.edges(), vec![(0, 1), (1, 2), (2, 3), (3, 0)]);
    }

    #[test]
    fn rejects_bad_orders() {
        assert!(Ring::new(vec![0, 1]).is_err());
        assert!(Ring::new(vec![0, 1, 1]).is_err());
        assert!(Ring::new(vec![0, 1, 5]).is_err());
    }

    #[test]
    fn induced_graph_degree_two() {
        let r = Ring::new(vec![2, 0, 3, 1]).unwrap();
        let g = r.to_graph(&unit_latency(4));
        for u in 0..4 {
            assert_eq!(g.degree(u), 2);
        }
        assert_eq!(g.m(), 4);
    }

    #[test]
    fn length_sums_edges() {
        let w = LatencyMatrix::from_fn(3, |u, v| {
            if u == v {
                0.0
            } else {
                (u + v) as f32
            }
        });
        let r = Ring::identity(3);
        // edges (0,1)=1, (1,2)=3, (2,0)=2 -> 6
        assert_eq!(r.length(&w), 6.0);
    }

    #[test]
    fn canonical_identifies_rotations_and_reflections() {
        let a = Ring::new(vec![0, 1, 2, 3]).unwrap();
        let b = Ring::new(vec![2, 3, 0, 1]).unwrap(); // rotation
        let c = Ring::new(vec![0, 3, 2, 1]).unwrap(); // reflection
        assert_eq!(a.canonical(), b.canonical());
        assert_eq!(a.canonical(), c.canonical());
        let d = Ring::new(vec![0, 2, 1, 3]).unwrap(); // different cycle
        assert_ne!(a.canonical(), d.canonical());
    }
}
