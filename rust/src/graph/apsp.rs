//! Shortest paths: single-source Dijkstra, all-pairs (APSP), and an
//! incremental edge-relaxation update mirroring python/compile/diameter.py.
//!
//! APSP is the hot loop of every experiment (the genetic baseline alone
//! evaluates up to 1e5 candidate topologies) — see rust/benches/hotpath.rs
//! and EXPERIMENTS.md §Perf for the optimization history. The serial
//! kernels here are source-parallelized by [`super::eval::EvalPool`]
//! (`apsp_par` stripes sources across threads over one shared CSR).

use std::collections::BinaryHeap;

use super::Graph;

/// Unreachable-pair distance marker.
pub const INF: f32 = f32::INFINITY;

/// Dense all-pairs distance matrix, row-major. `INF` = unreachable.
#[derive(Clone, Debug)]
pub struct DistMatrix {
    /// Number of nodes (the matrix is n x n).
    pub n: usize,
    /// Row-major distances; `d[u * n + v]` = dist(u, v).
    pub d: Vec<f32>,
}

impl DistMatrix {
    /// An all-[`INF`] matrix with a zero diagonal (SSSP fills the rest).
    pub fn new_empty(n: usize) -> DistMatrix {
        let mut d = vec![INF; n * n];
        for i in 0..n {
            d[i * n + i] = 0.0;
        }
        DistMatrix { n, d }
    }

    #[inline]
    /// Distance from `u` to `v`.
    pub fn get(&self, u: usize, v: usize) -> f32 {
        self.d[u * self.n + v]
    }

    #[inline]
    /// Set the distance from `u` to `v` (directed cell).
    pub fn set(&mut self, u: usize, v: usize, w: f32) {
        self.d[u * self.n + v] = w;
    }

    /// The full distance row of source `u`.
    pub fn row(&self, u: usize) -> &[f32] {
        &self.d[u * self.n..(u + 1) * self.n]
    }
}

/// Heap keys pack (distance bits, node) into one u64: for non-negative
/// finite f32, `to_bits()` is monotone in the float order, so integer
/// comparison == float comparison and the hot heap avoids f32
/// `partial_cmp` entirely (EXPERIMENTS.md §Perf, L3 iteration 3).
#[inline]
fn heap_key(dist: f32, node: u32) -> u64 {
    debug_assert!(dist >= 0.0);
    ((dist.to_bits() as u64) << 32) | node as u64
}

/// Single-source shortest paths (non-negative weights). Writes distances
/// into `dist` (len n); `heap` is a caller-provided scratch so the APSP
/// loop reuses one allocation across all N sources.
pub fn dijkstra_scratch(
    g: &Graph,
    src: usize,
    dist: &mut [f32],
    heap: &mut BinaryHeap<std::cmp::Reverse<u64>>,
) {
    let n = g.n();
    debug_assert_eq!(dist.len(), n);
    dist.fill(INF);
    dist[src] = 0.0;
    heap.clear();
    heap.push(std::cmp::Reverse(heap_key(0.0, src as u32)));
    while let Some(std::cmp::Reverse(key)) = heap.pop() {
        let u = (key & 0xFFFF_FFFF) as usize;
        let du = f32::from_bits((key >> 32) as u32);
        if du > dist[u] {
            continue; // stale entry
        }
        for &(v, w) in g.neighbors(u) {
            let v = v as usize;
            let alt = du + w;
            if alt < dist[v] {
                dist[v] = alt;
                heap.push(std::cmp::Reverse(heap_key(alt, v as u32)));
            }
        }
    }
}

/// Single-source shortest paths into a caller buffer.
pub fn dijkstra_into(g: &Graph, src: usize, dist: &mut [f32]) {
    let mut heap = BinaryHeap::with_capacity(g.n());
    dijkstra_scratch(g, src, dist, &mut heap);
}

/// Single-source shortest paths, allocating the output.
pub fn dijkstra(g: &Graph, src: usize) -> Vec<f32> {
    let mut dist = vec![INF; g.n()];
    dijkstra_into(g, src, &mut dist);
    dist
}

/// Flattened CSR adjacency: one contiguous edge array instead of
/// per-node Vecs, so the N Dijkstra sweeps of APSP stream memory
/// (EXPERIMENTS.md §Perf, L3 iteration 4).
pub struct Csr {
    offsets: Vec<u32>,
    targets: Vec<u32>,
    weights: Vec<f32>,
}

impl Csr {
    /// Build the CSR from an adjacency-list graph.
    ///
    /// Node ids and edge offsets are `u32` end-to-end — the compact
    /// layout that keeps a 10^6-node overlay's evaluation state in RAM
    /// (12 bytes per directed edge, 4 per node). Panics if `n` or the
    /// directed edge count exceeds `u32::MAX`; every evaluation path
    /// funnels through here, so the guard is checked exactly once.
    pub fn build(g: &Graph) -> Csr {
        let n = g.n();
        assert!(
            u32::try_from(n).is_ok(),
            "CSR node ids are u32: graph has {n} nodes"
        );
        assert!(
            u32::try_from(2 * g.m()).is_ok(),
            "CSR offsets are u32: graph has {} undirected edges",
            g.m()
        );
        let mut offsets = Vec::with_capacity(n + 1);
        let mut targets = Vec::with_capacity(2 * g.m());
        let mut weights = Vec::with_capacity(2 * g.m());
        offsets.push(0);
        for u in 0..n {
            for &(v, w) in g.neighbors(u) {
                targets.push(v);
                weights.push(w);
            }
            offsets.push(targets.len() as u32);
        }
        Csr {
            offsets,
            targets,
            weights,
        }
    }

    /// Resident size of the flattened arrays in bytes — the dominant
    /// term of the evaluation memory model (docs/SCENARIOS.md §Scaling
    /// & certification); folded into `eval.peak_scratch_bytes`.
    pub fn bytes(&self) -> usize {
        4 * self.offsets.len()
            + 4 * self.targets.len()
            + 4 * self.weights.len()
    }

    #[inline]
    /// Dijkstra from `src` into `dist`, reusing a caller-owned heap
    /// so steady-state sweeps allocate nothing.
    pub fn dijkstra_scratch(
        &self,
        src: usize,
        dist: &mut [f32],
        heap: &mut BinaryHeap<std::cmp::Reverse<u64>>,
    ) {
        dist.fill(INF);
        dist[src] = 0.0;
        heap.clear();
        heap.push(std::cmp::Reverse(heap_key(0.0, src as u32)));
        while let Some(std::cmp::Reverse(key)) = heap.pop() {
            let u = (key & 0xFFFF_FFFF) as usize;
            let du = f32::from_bits((key >> 32) as u32);
            if du > dist[u] {
                continue;
            }
            let (lo, hi) =
                (self.offsets[u] as usize, self.offsets[u + 1] as usize);
            for i in lo..hi {
                let v = self.targets[i] as usize;
                let alt = du + self.weights[i];
                if alt < dist[v] {
                    dist[v] = alt;
                    heap.push(std::cmp::Reverse(heap_key(alt, v as u32)));
                }
            }
        }
    }
}

/// All-pairs shortest paths: Dijkstra from every source over a CSR
/// flattening. O(N * (N + E) log N); the `hotpath` bench tracks this.
pub fn apsp(g: &Graph) -> DistMatrix {
    let n = g.n();
    let mut out = DistMatrix {
        n,
        d: vec![INF; n * n],
    };
    let csr = Csr::build(g);
    let mut heap = BinaryHeap::with_capacity(n);
    let mut rows = out.d.chunks_mut(n);
    for s in 0..n {
        let row = rows.next().expect("n rows");
        csr.dijkstra_scratch(s, row, &mut heap);
    }
    out
}

/// Floyd–Warshall APSP (O(N^3)) — the oracle the property tests compare
/// Dijkstra-APSP against; also used for very dense graphs where it wins.
pub fn floyd_warshall(g: &Graph) -> DistMatrix {
    let n = g.n();
    let mut dm = DistMatrix::new_empty(n);
    for u in 0..n {
        for &(v, w) in g.neighbors(u) {
            let v = v as usize;
            if w < dm.get(u, v) {
                dm.set(u, v, w);
            }
        }
    }
    for k in 0..n {
        for i in 0..n {
            let dik = dm.get(i, k);
            if dik == INF {
                continue;
            }
            // Row-sliced inner loop: d[i][j] = min(d[i][j], d[i][k]+d[k][j])
            let (krow_start, irow_start) = (k * n, i * n);
            for j in 0..n {
                let alt = dik + dm.d[krow_start + j];
                if alt < dm.d[irow_start + j] {
                    dm.d[irow_start + j] = alt;
                }
            }
        }
    }
    dm
}

/// Incremental APSP: relax every pair through a new undirected edge
/// (u, v, w). `dist` must be the exact APSP of the graph without the edge;
/// afterwards it is exact for the graph with it. O(N^2). Mirror of
/// python/compile/diameter.py::add_edge (shared semantics with training).
pub fn relax_edge(dm: &mut DistMatrix, u: usize, v: usize, w: f32) {
    let n = dm.n;
    if w >= dm.get(u, v) {
        return;
    }
    let du: Vec<f32> = (0..n).map(|i| dm.get(i, u)).collect();
    let dv: Vec<f32> = (0..n).map(|i| dm.get(i, v)).collect();
    for i in 0..n {
        let base_uv = du[i] + w; // i -> u -> v -> j
        let base_vu = dv[i] + w; // i -> v -> u -> j
        if base_uv == INF && base_vu == INF {
            continue;
        }
        let row = &mut dm.d[i * n..(i + 1) * n];
        for j in 0..n {
            let a = base_uv + dv[j];
            if a < row[j] {
                row[j] = a;
            }
            let b = base_vu + du[j];
            if b < row[j] {
                row[j] = b;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_graph(rng: &mut Rng, n: usize, m: usize) -> Graph {
        let mut g = Graph::empty(n);
        while g.m() < m {
            let u = rng.index(n);
            let v = rng.index(n);
            if u != v {
                g.add_edge(u, v, rng.range_i64(1, 10) as f32);
            }
        }
        g
    }

    #[test]
    fn dijkstra_line_graph() {
        let g = Graph::from_weighted_edges(
            4,
            &[(0, 1, 1.0), (1, 2, 2.0), (2, 3, 4.0)],
        );
        let d = dijkstra(&g, 0);
        assert_eq!(d, vec![0.0, 1.0, 3.0, 7.0]);
    }

    #[test]
    fn dijkstra_prefers_shorter_path() {
        let g = Graph::from_weighted_edges(
            3,
            &[(0, 1, 10.0), (0, 2, 1.0), (2, 1, 1.0)],
        );
        let d = dijkstra(&g, 0);
        assert_eq!(d[1], 2.0);
    }

    #[test]
    fn dijkstra_unreachable_is_inf() {
        let g = Graph::from_weighted_edges(4, &[(0, 1, 1.0)]);
        let d = dijkstra(&g, 0);
        assert_eq!(d[2], INF);
        assert_eq!(d[3], INF);
    }

    #[test]
    fn apsp_matches_floyd_warshall_random() {
        let mut rng = Rng::new(2024);
        for trial in 0..10 {
            let n = 8 + 4 * (trial % 4);
            let g = random_graph(&mut rng, n, 2 * n);
            let a = apsp(&g);
            let b = floyd_warshall(&g);
            for i in 0..n {
                for j in 0..n {
                    let (x, y) = (a.get(i, j), b.get(i, j));
                    if x == INF || y == INF {
                        assert_eq!(x, y, "({i},{j}) trial {trial}");
                    } else {
                        assert!((x - y).abs() < 1e-4, "({i},{j}): {x} vs {y}");
                    }
                }
            }
        }
    }

    #[test]
    fn apsp_symmetric_for_undirected() {
        let mut rng = Rng::new(7);
        let g = random_graph(&mut rng, 16, 32);
        let dm = apsp(&g);
        for i in 0..16 {
            for j in 0..16 {
                let (x, y) = (dm.get(i, j), dm.get(j, i));
                if x == INF {
                    assert_eq!(y, INF);
                } else {
                    assert!((x - y).abs() < 1e-4);
                }
            }
        }
    }

    #[test]
    fn relax_edge_matches_recompute() {
        let mut rng = Rng::new(99);
        let mut g = random_graph(&mut rng, 12, 18);
        let mut dm = apsp(&g);
        // Add 8 random new edges, relaxing incrementally each time.
        for _ in 0..8 {
            let u = rng.index(12);
            let v = (u + 1 + rng.index(11)) % 12;
            let w = rng.range_i64(1, 10) as f32;
            relax_edge(&mut dm, u, v, w);
            g.add_edge(u, v, w);
            // add_edge keeps min weight; relax_edge no-ops on worse
            // parallel edges, matching.
            let fresh = apsp(&g);
            for i in 0..12 {
                for j in 0..12 {
                    let (x, y) = (dm.get(i, j), fresh.get(i, j));
                    if x == INF || y == INF {
                        assert_eq!(x, y);
                    } else {
                        assert!((x - y).abs() < 1e-4, "({i},{j}): {x} vs {y}");
                    }
                }
            }
        }
    }

    #[test]
    fn dist_matrix_empty_has_zero_diag() {
        let dm = DistMatrix::new_empty(3);
        assert_eq!(dm.get(0, 0), 0.0);
        assert_eq!(dm.get(0, 1), INF);
    }
}
