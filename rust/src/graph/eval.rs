//! Parallel, allocation-free topology evaluation — the engine behind
//! every diameter-scored experiment (GA candidate populations, scenario
//! periods, `scenario compare` cross products).
//!
//! [`EvalPool`] stripes work over `threads` OS threads
//! (`std::thread::scope`; no rayon offline, DESIGN.md §3) and recycles
//! per-worker Dijkstra scratch — the bit-packed `(f32 bits, node)` heap
//! of [`super::apsp`] — through a checkout pool, so the steady-state
//! SSSP sweep allocates nothing. Distance rows are written straight into
//! caller-owned buffers (the APSP matrix, the bounding algorithm's
//! per-round block), never copied.
//!
//!   * [`EvalPool::apsp_par`] — all-pairs shortest paths over one shared
//!     read-only CSR, sources striped across threads in contiguous row
//!     blocks (each worker owns a disjoint slice of the output matrix).
//!   * [`EvalPool::diameter_par`] / [`EvalPool::diameter_with_seeds`] —
//!     the Takes–Kosters bounding algorithm of [`super::diameter`] with
//!     each round's SSSP sweeps run in parallel, optionally warm-started
//!     from landmark nodes (the scenario engine feeds the previous
//!     period's certifying sources back in).
//!   * [`EvalPool::diameter_batch`] — a whole candidate population
//!     evaluated concurrently, one graph per task, via
//!     [`crate::par::scoped_map`].
//!
//! Exactness and determinism: `apsp_par` and `diameter_batch` are
//! bit-identical to their serial counterparts (same per-task algorithm;
//! threads only partition independent work). The bounding diameter's
//! sweep *schedule* is fixed at [`ROUND_WIDTH`] sources per round
//! regardless of pool width, so its certified value is bit-identical
//! across thread counts and machines — `threads` only bounds how many
//! of a round's sweeps run concurrently — and agrees with the serial
//! `diameter()` within the certification tolerance (~1e-6 of the
//! scale). `rust/tests/proptests.rs` pins all of this across thread
//! counts {1, 2, 8}, and `rust/benches/hotpath.rs` records the
//! serial-vs-parallel trajectory in `BENCH_hotpath.json`.

use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::apsp::{Csr, DistMatrix, INF};
use super::components;
use super::diameter;
use super::Graph;

/// Warm-start landmarks retained per diameter call: the certifying
/// sources with the largest eccentricities. Enough to re-certify a
/// barely-changed overlay in one round without bloating the warm-up
/// cost when the overlay did change.
pub const MAX_LANDMARKS: usize = 4;

/// Sources swept per bounding-diameter round. Fixed — deliberately NOT
/// the pool width — so the sweep schedule (and therefore the certified
/// value, exact up to the usual 1e-6 certification fudge) is a pure
/// function of (graph, seeds): reports stay byte-identical across
/// `--threads` settings and machines. Equal to [`MAX_LANDMARKS`] so a
/// warm round covers the whole landmark set, and small enough that the
/// round-granular schedule wastes at most a couple of sweeps over the
/// serial one-at-a-time heuristic.
pub const ROUND_WIDTH: usize = 4;

/// Reusable per-worker Dijkstra state (checked out of [`EvalPool`] for
/// the duration of one worker's run, returned afterwards).
#[derive(Default)]
struct DijkstraScratch {
    heap: BinaryHeap<std::cmp::Reverse<u64>>,
}

/// A fixed-width evaluation pool: `threads` workers, recycled scratch.
///
/// The pool itself is cheap (no OS threads are parked; workers are
/// scoped per call) — construct one near the work loop and reuse it so
/// the scratch heaps stay warm.
pub struct EvalPool {
    threads: usize,
    scratch: Mutex<Vec<DijkstraScratch>>,
    /// `eval.sweeps` registry counter (None until
    /// [`EvalPool::attach_obs`]): SSSP sources processed by the
    /// bounding algorithm.
    obs_sweeps: Option<Arc<AtomicU64>>,
    /// `eval.warm_hits` registry counter: warm-start landmarks that
    /// were still live candidates when their round started.
    obs_warm_hits: Option<Arc<AtomicU64>>,
}

impl EvalPool {
    /// A pool of `threads` workers (clamped to ≥ 1).
    pub fn new(threads: usize) -> EvalPool {
        EvalPool {
            threads: threads.max(1),
            scratch: Mutex::new(Vec::new()),
            obs_sweeps: None,
            obs_warm_hits: None,
        }
    }

    /// Route sweep accounting into `obs`: `eval.sweeps` counts every
    /// SSSP source the bounding algorithm processes,
    /// `eval.warm_hits` counts warm-start landmarks that paid off
    /// (their hit rate is the warm-start efficiency). Counters are
    /// atomic, so attached pools stay shareable across workers.
    pub fn attach_obs(&mut self, obs: &crate::obs::Obs) {
        self.obs_sweeps = Some(obs.reg.counter("eval.sweeps"));
        self.obs_warm_hits = Some(obs.reg.counter("eval.warm_hits"));
    }

    /// One worker: bit-for-bit the serial algorithms, same scratch reuse.
    pub fn serial() -> EvalPool {
        EvalPool::new(1)
    }

    /// The machine's core count (the CLI's `--threads 0` resolution).
    pub fn default_threads() -> usize {
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
    }

    /// The pool width this instance was built with.
    pub fn threads(&self) -> usize {
        self.threads
    }

    fn checkout(&self) -> DijkstraScratch {
        self.scratch.lock().unwrap().pop().unwrap_or_default()
    }

    fn checkin(&self, s: DijkstraScratch) {
        self.scratch.lock().unwrap().push(s);
    }

    /// All-pairs shortest paths, sources striped across the pool.
    /// Identical output to [`super::apsp::apsp`] (same per-row
    /// algorithm; rows are independent).
    pub fn apsp_par(&self, g: &Graph) -> DistMatrix {
        let n = g.n();
        let mut out = DistMatrix {
            n,
            d: vec![INF; n * n],
        };
        if n == 0 {
            return out;
        }
        let csr = Csr::build(g);
        let threads = self.threads.min(n);
        if threads <= 1 {
            let mut sc = self.checkout();
            for (s, row) in out.d.chunks_mut(n).enumerate() {
                csr.dijkstra_scratch(s, row, &mut sc.heap);
            }
            self.checkin(sc);
            return out;
        }
        let rows_per = (n + threads - 1) / threads;
        let csr_ref = &csr;
        let this = &*self;
        std::thread::scope(|scope| {
            for (ci, block) in out.d.chunks_mut(rows_per * n).enumerate() {
                scope.spawn(move || {
                    let mut sc = this.checkout();
                    for (ri, row) in block.chunks_mut(n).enumerate() {
                        csr_ref.dijkstra_scratch(
                            ci * rows_per + ri,
                            row,
                            &mut sc.heap,
                        );
                    }
                    this.checkin(sc);
                });
            }
        });
        out
    }

    /// Exact diameter (largest component), Takes–Kosters sweeps run in
    /// fixed-width rounds across the pool. Bit-identical across thread
    /// counts; agrees with [`super::diameter::diameter`] within the
    /// certification tolerance.
    pub fn diameter_par(&self, g: &Graph) -> f32 {
        self.diameter_with_seeds(g, &[]).0
    }

    /// Exact diameter with warm-start landmarks: `seeds` are processed
    /// as the first SSSP sources (non-members are skipped), which lets a
    /// caller that evaluates a slowly-changing overlay re-certify in a
    /// round or two. Returns `(diameter, landmarks)` where `landmarks`
    /// are the up-to-[`MAX_LANDMARKS`] processed sources with the
    /// largest eccentricities — feed them back in as the next call's
    /// `seeds`. The value is exact regardless of seeds or thread count.
    pub fn diameter_with_seeds(
        &self,
        g: &Graph,
        seeds: &[u32],
    ) -> (f32, Vec<u32>) {
        let n = g.n();
        if n == 0 || g.m() == 0 {
            return (0.0, Vec::new());
        }
        let members = components::largest(&components::components(g));
        if members.len() < 2 {
            return (0.0, Vec::new());
        }

        let csr = Csr::build(g);
        // The schedule width is fixed (see [`ROUND_WIDTH`]); the pool
        // width only decides how many sweeps run concurrently.
        let width = ROUND_WIDTH.min(members.len()).max(1);
        // One distance row per in-flight sweep, reused every round.
        let mut batch_dist = vec![INF; width * n];

        let mut member_mask = vec![false; n];
        for &u in &members {
            member_mask[u as usize] = true;
        }
        // Warm-start queue (members only, deduplicated, caller order).
        let mut seed_queue: Vec<u32> = Vec::new();
        for &s in seeds {
            if (s as usize) < n
                && member_mask[s as usize]
                && !seed_queue.contains(&s)
            {
                seed_queue.push(s);
            }
        }
        seed_queue.reverse(); // consumed by pop() in caller order

        let mut ecc_lo = vec![0.0f32; n];
        let mut ecc_hi = vec![f32::INFINITY; n];
        let mut cand: Vec<u32> = members.clone();
        let mut lb = 0.0f32;
        let mut pick_hi = true;
        // (source, exact eccentricity) of every processed sweep.
        let mut processed: Vec<(u32, f32)> = Vec::new();

        while !cand.is_empty() {
            // Assemble the round: landmarks first, then the serial
            // algorithm's alternating max-upper / max-lower picks.
            let mut batch: Vec<u32> = Vec::with_capacity(width);
            while batch.len() < width {
                let src = if let Some(s) = seed_queue.pop() {
                    match cand.iter().position(|&u| u == s) {
                        Some(i) => {
                            if let Some(c) = &self.obs_warm_hits {
                                c.fetch_add(1, Ordering::Relaxed);
                            }
                            cand.swap_remove(i)
                        }
                        None => continue, // already pruned
                    }
                } else if cand.is_empty() {
                    break;
                } else {
                    let (idx, _) = cand
                        .iter()
                        .enumerate()
                        .map(|(i, &u)| {
                            let score = if pick_hi {
                                ecc_hi[u as usize]
                            } else {
                                ecc_lo[u as usize]
                            };
                            (i, score)
                        })
                        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                        .unwrap();
                    pick_hi = !pick_hi;
                    cand.swap_remove(idx)
                };
                batch.push(src);
            }
            if batch.is_empty() {
                break;
            }
            if let Some(c) = &self.obs_sweeps {
                c.fetch_add(batch.len() as u64, Ordering::Relaxed);
            }

            // The round's SSSPs. Row i of `batch_dist` always belongs
            // to `batch[i]`, however the sweeps are distributed.
            let workers = self.threads.min(batch.len());
            if workers <= 1 {
                let mut sc = self.checkout();
                for (row, &src) in
                    batch_dist.chunks_mut(n).zip(batch.iter())
                {
                    csr.dijkstra_scratch(src as usize, row, &mut sc.heap);
                }
                self.checkin(sc);
            } else {
                let mut bins: Vec<Vec<(u32, &mut [f32])>> =
                    (0..workers).map(|_| Vec::new()).collect();
                for (i, (row, &src)) in batch_dist
                    .chunks_mut(n)
                    .zip(batch.iter())
                    .enumerate()
                {
                    bins[i % workers].push((src, row));
                }
                let csr_ref = &csr;
                let this = &*self;
                std::thread::scope(|scope| {
                    for bin in bins {
                        scope.spawn(move || {
                            let mut sc = this.checkout();
                            for (src, row) in bin {
                                csr_ref.dijkstra_scratch(
                                    src as usize,
                                    row,
                                    &mut sc.heap,
                                );
                            }
                            this.checkin(sc);
                        });
                    }
                });
            }

            // Sequential bound tightening, exactly the serial rule,
            // applied once per completed sweep.
            for (bi, &v) in batch.iter().enumerate() {
                let dist = &batch_dist[bi * n..(bi + 1) * n];
                let mut ecc_v = 0.0f32;
                for &u in &members {
                    let d = dist[u as usize];
                    if d.is_finite() && d > ecc_v {
                        ecc_v = d;
                    }
                }
                if ecc_v > lb {
                    lb = ecc_v;
                }
                processed.push((v, ecc_v));
                cand.retain(|&u| {
                    let u = u as usize;
                    let d = dist[u];
                    if d.is_finite() {
                        let lo = (ecc_v - d).max(d);
                        if lo > ecc_lo[u] {
                            ecc_lo[u] = lo;
                        }
                        let hi = ecc_v + d;
                        if hi < ecc_hi[u] {
                            ecc_hi[u] = hi;
                        }
                    }
                    if ecc_lo[u] > lb {
                        lb = ecc_lo[u];
                    }
                    ecc_hi[u] > lb + 1e-6
                });
            }
        }

        // Keep the far-out sources as next-call landmarks.
        processed.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        processed.truncate(MAX_LANDMARKS);
        (lb, processed.into_iter().map(|(v, _)| v).collect())
    }

    /// Diameter of every graph in a candidate population, one task per
    /// graph across the pool. Values are identical to calling
    /// [`super::diameter::diameter`] per graph (each task IS that call).
    pub fn diameter_batch(&self, gs: &[Graph]) -> Vec<f32> {
        if self.threads <= 1 || gs.len() <= 1 {
            return gs.iter().map(diameter::diameter).collect();
        }
        let idx: Vec<usize> = (0..gs.len()).collect();
        crate::par::scoped_map(idx, self.threads, |_, i| {
            diameter::diameter(&gs[i])
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::apsp;
    use crate::latency::Model;
    use crate::topology::{kring, paper_k};
    use crate::util::rng::Rng;

    fn overlay(n: usize, seed: u64) -> Graph {
        let mut rng = Rng::new(seed);
        let w = Model::Uniform.sample(n, &mut rng);
        kring::random_krings(n, paper_k(n), &mut rng).to_graph(&w)
    }

    #[test]
    fn apsp_par_matches_serial_bitwise() {
        let g = overlay(48, 0xE7A1);
        let serial = apsp::apsp(&g);
        for threads in [1, 2, 3, 8] {
            let pool = EvalPool::new(threads);
            let par = pool.apsp_par(&g);
            assert_eq!(serial.n, par.n);
            for (a, b) in serial.d.iter().zip(&par.d) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}");
            }
        }
    }

    #[test]
    fn diameter_par_matches_serial() {
        for trial in 0..6 {
            let n = 16 + 11 * trial;
            let g = overlay(n, 0xD1A + trial as u64);
            let serial = diameter::diameter(&g);
            for threads in [1, 2, 8] {
                let pool = EvalPool::new(threads);
                let par = pool.diameter_par(&g);
                assert!(
                    (par - serial).abs() <= 1e-3 * serial.max(1.0),
                    "n={n} threads={threads}: {par} vs {serial}"
                );
            }
        }
    }

    #[test]
    fn warm_seeds_do_not_change_the_value() {
        let g = overlay(40, 7);
        let serial = diameter::diameter(&g);
        let pool = EvalPool::new(4);
        let (d0, landmarks) = pool.diameter_with_seeds(&g, &[]);
        assert!((d0 - serial).abs() <= 1e-3 * serial.max(1.0));
        assert!(!landmarks.is_empty() && landmarks.len() <= MAX_LANDMARKS);
        // Re-certify from the landmarks (the scenario engine's pattern),
        // and from garbage seeds including out-of-range ids.
        let (d1, _) = pool.diameter_with_seeds(&g, &landmarks);
        assert!((d1 - serial).abs() <= 1e-3 * serial.max(1.0));
        let (d2, _) = pool.diameter_with_seeds(&g, &[0, 0, 39, 1000]);
        assert!((d2 - serial).abs() <= 1e-3 * serial.max(1.0));
    }

    #[test]
    fn diameter_batch_matches_per_graph_serial() {
        let gs: Vec<Graph> =
            (0..7).map(|i| overlay(20 + i, 100 + i as u64)).collect();
        let serial: Vec<f32> =
            gs.iter().map(diameter::diameter).collect();
        for threads in [1, 2, 8] {
            let pool = EvalPool::new(threads);
            assert_eq!(pool.diameter_batch(&gs), serial);
        }
    }

    #[test]
    fn degenerate_graphs() {
        let pool = EvalPool::new(4);
        let empty = Graph::empty(0);
        assert_eq!(pool.apsp_par(&empty).d.len(), 0);
        assert_eq!(pool.diameter_par(&empty), 0.0);
        let edgeless = Graph::empty(5);
        assert_eq!(pool.diameter_par(&edgeless), 0.0);
        assert_eq!(pool.diameter_with_seeds(&edgeless, &[1, 2]).0, 0.0);
        assert!(pool.diameter_batch(&[]).is_empty());
        // Disconnected: largest component rules, same as serial.
        let g = Graph::from_weighted_edges(
            6,
            &[(0, 1, 1.0), (1, 2, 1.0), (3, 4, 9.0)],
        );
        assert_eq!(pool.diameter_par(&g), diameter::diameter(&g));
    }
}
