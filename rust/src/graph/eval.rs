//! Parallel, allocation-free topology evaluation — the engine behind
//! every diameter-scored experiment (GA candidate populations, scenario
//! periods, `scenario compare` cross products).
//!
//! [`EvalPool`] stripes work over `threads` OS threads
//! (`std::thread::scope`; no rayon offline, DESIGN.md §3) and recycles
//! per-worker Dijkstra scratch — the bit-packed `(f32 bits, node)` heap
//! of [`super::apsp`] — through a checkout pool, so the steady-state
//! SSSP sweep allocates nothing. Distance rows are written straight into
//! caller-owned buffers (the APSP matrix, the bounding algorithm's
//! per-round block), never copied.
//!
//!   * [`EvalPool::apsp_par`] — all-pairs shortest paths over one shared
//!     read-only CSR, sources striped across threads in contiguous row
//!     blocks (each worker owns a disjoint slice of the output matrix).
//!   * [`EvalPool::diameter_par`] / [`EvalPool::diameter_with_seeds`] —
//!     the Takes–Kosters bounding algorithm of [`super::diameter`] with
//!     each round's SSSP sweeps run in parallel, optionally warm-started
//!     from landmark nodes (the scenario engine feeds the previous
//!     period's certifying sources back in).
//!   * [`EvalPool::diameter_est`] — the same bounding sweep stopped at a
//!     landmark budget: a certified `[lower, upper]` diameter interval
//!     for overlays too large to certify exactly every period (the
//!     `--certify hybrid|sketch` scale tier, docs/SCENARIOS.md).
//!   * [`EvalPool::diameter_batch`] — a whole candidate population
//!     evaluated concurrently, one graph per task, via
//!     [`crate::par::scoped_map`].
//!
//! Exactness and determinism: `apsp_par` and `diameter_batch` are
//! bit-identical to their serial counterparts (same per-task algorithm;
//! threads only partition independent work). The bounding diameter's
//! sweep *schedule* is fixed at [`ROUND_WIDTH`] sources per round
//! regardless of pool width, so its certified value — and the budgeted
//! estimator's `[lower, upper]` interval — is bit-identical across
//! thread counts and machines; `threads` only bounds how many of a
//! round's sweeps run concurrently. The exact value agrees with the
//! serial `diameter()` within the certification tolerance (~1e-6 of
//! the scale), and the interval always brackets it: `lower` is a
//! realized eccentricity, `upper` dominates every member's eccentricity
//! bound. `rust/tests/proptests.rs` pins all of this across thread
//! counts {1, 2, 8} and landmark budgets {4, 16, 64}, and
//! `rust/benches/hotpath.rs` records the serial-vs-parallel and
//! scale-tier trajectories in `BENCH_hotpath.json`.

use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::apsp::{Csr, DistMatrix, INF};
use super::components;
use super::diameter;
use super::Graph;

/// Warm-start landmarks retained per diameter call: the certifying
/// sources with the largest eccentricities. Enough to re-certify a
/// barely-changed overlay in one round without bloating the warm-up
/// cost when the overlay did change.
pub const MAX_LANDMARKS: usize = 4;

/// Sources swept per bounding-diameter round. Fixed — deliberately NOT
/// the pool width — so the sweep schedule (and therefore the certified
/// value, exact up to the usual 1e-6 certification fudge) is a pure
/// function of (graph, seeds): reports stay byte-identical across
/// `--threads` settings and machines. Equal to [`MAX_LANDMARKS`] so a
/// warm round covers the whole landmark set, and small enough that the
/// round-granular schedule wastes at most a couple of sweeps over the
/// serial one-at-a-time heuristic.
pub const ROUND_WIDTH: usize = 4;

/// How scenario-period diameters are certified (`--certify`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CertifyMode {
    /// Run the Takes–Kosters sweep to convergence every evaluation —
    /// the reported diameter is exact (the pre-scale-tier behavior).
    Exact,
    /// Budgeted estimates every evaluation, plus the exact oracle on
    /// every k-th one; the oracle value is reported on those periods
    /// and must land inside the estimator's `[lower, upper]` interval.
    Hybrid,
    /// Budgeted estimates only: report the certified upper bound and
    /// never pay for convergence (the 10^5+-node tier).
    Sketch,
}

impl CertifyMode {
    /// Parse a `--certify` value.
    pub fn parse(s: &str) -> Option<CertifyMode> {
        match s {
            "exact" => Some(CertifyMode::Exact),
            "hybrid" => Some(CertifyMode::Hybrid),
            "sketch" => Some(CertifyMode::Sketch),
            _ => None,
        }
    }

    /// The CLI name.
    pub fn name(self) -> &'static str {
        match self {
            CertifyMode::Exact => "exact",
            CertifyMode::Hybrid => "hybrid",
            CertifyMode::Sketch => "sketch",
        }
    }
}

/// Certification policy: mode plus the estimator knobs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CertifyConfig {
    /// Exact, hybrid or sketch (see [`CertifyMode`]).
    pub mode: CertifyMode,
    /// Landmark budget: SSSP sweeps per estimate (`--landmarks`).
    pub budget: usize,
    /// Hybrid cadence: run the exact oracle on every k-th evaluation
    /// (`--oracle-every`). Ignored by exact and sketch modes.
    pub oracle_every: usize,
}

impl CertifyConfig {
    /// The default exact policy (estimator knobs at their defaults so
    /// switching just the mode behaves sensibly).
    pub fn exact() -> CertifyConfig {
        CertifyConfig {
            mode: CertifyMode::Exact,
            budget: 16,
            oracle_every: 8,
        }
    }

    /// True when every evaluation runs to convergence.
    pub fn is_exact(&self) -> bool {
        self.mode == CertifyMode::Exact
    }

    /// Whether evaluation number `idx` (0-based) is a hybrid oracle
    /// period: exact certification plus a bracket check.
    pub fn oracle_period(&self, idx: u64) -> bool {
        self.mode == CertifyMode::Hybrid
            && idx % self.oracle_every.max(1) as u64 == 0
    }

    /// Reject nonsensical knob values before a run starts.
    pub fn validate(&self) -> Result<(), String> {
        if self.budget == 0 {
            return Err("--landmarks must be >= 1".into());
        }
        if self.oracle_every == 0 {
            return Err("--oracle-every must be >= 1".into());
        }
        Ok(())
    }
}

impl Default for CertifyConfig {
    fn default() -> CertifyConfig {
        CertifyConfig::exact()
    }
}

/// A certified diameter interval from a budgeted bounding sweep.
///
/// Invariant (pinned by rust/tests/proptests.rs): the exact diameter
/// `D` of the largest component satisfies `lower <= D <= upper`.
/// `lower` is the largest realized lower bound, `upper` the largest
/// surviving per-member eccentricity upper bound; both are pure
/// functions of `(graph, seeds, budget)` — thread-count invariant.
#[derive(Clone, Debug)]
pub struct DiameterEst {
    /// Certified lower bound (a realized eccentricity; exact mode
    /// converges to the diameter itself).
    pub lower: f32,
    /// Certified upper bound (max member eccentricity bound; collapses
    /// to `lower` within ~1e-6 once the sweep converges).
    pub upper: f32,
    /// Up to [`MAX_LANDMARKS`] swept sources with the largest
    /// eccentricities — the next call's warm-start seeds.
    pub landmarks: Vec<u32>,
    /// SSSP sources actually swept (<= the requested budget).
    pub sweeps: usize,
}

impl DiameterEst {
    /// `upper - lower` as a percentage of `upper` (0 when converged or
    /// the graph is degenerate) — the `eval.est_gap_pct` metric.
    pub fn gap_pct(&self) -> f64 {
        if self.upper <= 0.0 || !self.upper.is_finite() {
            return 0.0;
        }
        100.0 * f64::from(self.upper - self.lower) / f64::from(self.upper)
    }
}

/// Reusable per-worker Dijkstra state (checked out of [`EvalPool`] for
/// the duration of one worker's run, returned afterwards).
#[derive(Default)]
struct DijkstraScratch {
    heap: BinaryHeap<std::cmp::Reverse<u64>>,
}

/// Arena for one bounding-diameter run: the per-round distance block
/// and the per-node bound arrays. Checked out per call and returned,
/// so a pool evaluating a slowly-changing overlay sizes these once per
/// epoch instead of reallocating ~(ROUND_WIDTH + 2) * n floats every
/// period.
#[derive(Default)]
struct EvalArena {
    batch_dist: Vec<f32>,
    ecc_lo: Vec<f32>,
    ecc_hi: Vec<f32>,
    member_mask: Vec<bool>,
}

impl EvalArena {
    /// Resize for an n-node graph and a `width`-sweep round, resetting
    /// values. Capacity is retained across calls (the arena reuse).
    fn reset(&mut self, n: usize, width: usize) {
        self.batch_dist.clear();
        self.batch_dist.resize(width * n, INF);
        self.ecc_lo.clear();
        self.ecc_lo.resize(n, 0.0);
        self.ecc_hi.clear();
        self.ecc_hi.resize(n, f32::INFINITY);
        self.member_mask.clear();
        self.member_mask.resize(n, false);
    }

    /// Logical footprint in bytes for the current (n, width) — a pure
    /// function of the sizing, so `eval.peak_scratch_bytes` stays
    /// deterministic across runs and thread counts.
    fn bytes(&self) -> usize {
        4 * self.batch_dist.len()
            + 4 * self.ecc_lo.len()
            + 4 * self.ecc_hi.len()
            + self.member_mask.len()
    }
}

/// A fixed-width evaluation pool: `threads` workers, recycled scratch.
///
/// The pool itself is cheap (no OS threads are parked; workers are
/// scoped per call) — construct one near the work loop and reuse it so
/// the scratch heaps and bound arenas stay warm.
pub struct EvalPool {
    threads: usize,
    scratch: Mutex<Vec<DijkstraScratch>>,
    arena: Mutex<Vec<EvalArena>>,
    /// `eval.sweeps` registry counter (None until
    /// [`EvalPool::attach_obs`]): SSSP sources processed by the
    /// bounding algorithm.
    obs_sweeps: Option<Arc<AtomicU64>>,
    /// `eval.warm_hits` registry counter: warm-start landmarks that
    /// were still live candidates when their round started.
    obs_warm_hits: Option<Arc<AtomicU64>>,
    /// `eval.peak_scratch_bytes` registry counter: high-water mark of
    /// CSR + arena bytes across evaluations (monotone max).
    obs_peak_scratch: Option<Arc<AtomicU64>>,
    /// `eval.est_gap_pct` registry histogram: estimator interval width
    /// as a percentage of the upper bound, one sample per
    /// [`EvalPool::diameter_est`] call.
    obs_est_gap: Option<Arc<crate::obs::registry::Histogram>>,
}

impl EvalPool {
    /// A pool of `threads` workers (clamped to ≥ 1).
    pub fn new(threads: usize) -> EvalPool {
        EvalPool {
            threads: threads.max(1),
            scratch: Mutex::new(Vec::new()),
            arena: Mutex::new(Vec::new()),
            obs_sweeps: None,
            obs_warm_hits: None,
            obs_peak_scratch: None,
            obs_est_gap: None,
        }
    }

    /// Route sweep accounting into `obs`: `eval.sweeps` counts every
    /// SSSP source the bounding algorithm processes, `eval.warm_hits`
    /// counts warm-start landmarks that paid off (their hit rate is
    /// the warm-start efficiency), `eval.peak_scratch_bytes` tracks
    /// the evaluation-state high-water mark (CSR + bound arena), and
    /// `eval.est_gap_pct` histograms the estimator's certified
    /// interval width. Counters are atomic, so attached pools stay
    /// shareable across workers.
    pub fn attach_obs(&mut self, obs: &crate::obs::Obs) {
        self.obs_sweeps = Some(obs.reg.counter("eval.sweeps"));
        self.obs_warm_hits = Some(obs.reg.counter("eval.warm_hits"));
        self.obs_peak_scratch =
            Some(obs.reg.counter("eval.peak_scratch_bytes"));
        self.obs_est_gap = Some(obs.reg.histogram("eval.est_gap_pct"));
    }

    /// One worker: bit-for-bit the serial algorithms, same scratch reuse.
    pub fn serial() -> EvalPool {
        EvalPool::new(1)
    }

    /// The machine's core count (the CLI's `--threads 0` resolution).
    pub fn default_threads() -> usize {
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
    }

    /// The pool width this instance was built with.
    pub fn threads(&self) -> usize {
        self.threads
    }

    fn checkout(&self) -> DijkstraScratch {
        self.scratch.lock().unwrap().pop().unwrap_or_default()
    }

    fn checkin(&self, s: DijkstraScratch) {
        self.scratch.lock().unwrap().push(s);
    }

    fn checkout_arena(&self) -> EvalArena {
        self.arena.lock().unwrap().pop().unwrap_or_default()
    }

    fn checkin_arena(&self, a: EvalArena) {
        self.arena.lock().unwrap().push(a);
    }

    /// All-pairs shortest paths, sources striped across the pool.
    /// Identical output to [`super::apsp::apsp`] (same per-row
    /// algorithm; rows are independent).
    pub fn apsp_par(&self, g: &Graph) -> DistMatrix {
        let n = g.n();
        let mut out = DistMatrix {
            n,
            d: vec![INF; n * n],
        };
        if n == 0 {
            return out;
        }
        let csr = Csr::build(g);
        let threads = self.threads.min(n);
        if threads <= 1 {
            let mut sc = self.checkout();
            for (s, row) in out.d.chunks_mut(n).enumerate() {
                csr.dijkstra_scratch(s, row, &mut sc.heap);
            }
            self.checkin(sc);
            return out;
        }
        let rows_per = (n + threads - 1) / threads;
        let csr_ref = &csr;
        let this = &*self;
        std::thread::scope(|scope| {
            for (ci, block) in out.d.chunks_mut(rows_per * n).enumerate() {
                scope.spawn(move || {
                    let mut sc = this.checkout();
                    for (ri, row) in block.chunks_mut(n).enumerate() {
                        csr_ref.dijkstra_scratch(
                            ci * rows_per + ri,
                            row,
                            &mut sc.heap,
                        );
                    }
                    this.checkin(sc);
                });
            }
        });
        out
    }

    /// Exact diameter (largest component), Takes–Kosters sweeps run in
    /// fixed-width rounds across the pool. Bit-identical across thread
    /// counts; agrees with [`super::diameter::diameter`] within the
    /// certification tolerance.
    pub fn diameter_par(&self, g: &Graph) -> f32 {
        self.diameter_with_seeds(g, &[]).0
    }

    /// Exact diameter with warm-start landmarks: `seeds` are processed
    /// as the first SSSP sources (non-members are skipped), which lets a
    /// caller that evaluates a slowly-changing overlay re-certify in a
    /// round or two. Returns `(diameter, landmarks)` where `landmarks`
    /// are the up-to-[`MAX_LANDMARKS`] processed sources with the
    /// largest eccentricities — feed them back in as the next call's
    /// `seeds`. The value is exact regardless of seeds or thread count.
    pub fn diameter_with_seeds(
        &self,
        g: &Graph,
        seeds: &[u32],
    ) -> (f32, Vec<u32>) {
        let est = self.bound_diameter(g, seeds, usize::MAX);
        (est.lower, est.landmarks)
    }

    /// Certified diameter interval under a landmark budget: the same
    /// bounding sweep as [`EvalPool::diameter_with_seeds`], stopped
    /// after at most `budget` SSSP sources (clamped to ≥ 1). The exact
    /// diameter always lies in `[lower, upper]`; with a large enough
    /// budget the interval collapses (within ~1e-6) and the call IS
    /// the exact certification. Cost is `O(budget * (n + m) log n)`
    /// regardless of how slowly the exact sweep would converge — the
    /// knob that makes 10^5–10^6-node evaluation affordable.
    pub fn diameter_est(
        &self,
        g: &Graph,
        seeds: &[u32],
        budget: usize,
    ) -> DiameterEst {
        let est = self.bound_diameter(g, seeds, budget.max(1));
        if let Some(h) = &self.obs_est_gap {
            h.observe(est.gap_pct());
        }
        est
    }

    /// The bounding sweep. `budget` caps how many SSSP sources are
    /// processed (`usize::MAX` = run to convergence). The schedule is
    /// a pure function of `(graph, seeds, budget)`.
    fn bound_diameter(
        &self,
        g: &Graph,
        seeds: &[u32],
        budget: usize,
    ) -> DiameterEst {
        let n = g.n();
        let degenerate = DiameterEst {
            lower: 0.0,
            upper: 0.0,
            landmarks: Vec::new(),
            sweeps: 0,
        };
        if n == 0 || g.m() == 0 {
            return degenerate;
        }
        let members = components::largest(&components::components(g));
        if members.len() < 2 {
            return degenerate;
        }

        let csr = Csr::build(g);
        // The schedule width is fixed (see [`ROUND_WIDTH`]); the pool
        // width only decides how many sweeps run concurrently.
        let width = ROUND_WIDTH.min(members.len()).max(1);
        let mut ar = self.checkout_arena();
        ar.reset(n, width);
        if let Some(c) = &self.obs_peak_scratch {
            let bytes = (csr.bytes() + ar.bytes()) as u64;
            c.fetch_max(bytes, Ordering::Relaxed);
        }
        let EvalArena {
            batch_dist,
            ecc_lo,
            ecc_hi,
            member_mask,
        } = &mut ar;

        for &u in &members {
            member_mask[u as usize] = true;
        }
        // Warm-start queue (members only, deduplicated, caller order).
        let mut seed_queue: Vec<u32> = Vec::new();
        for &s in seeds {
            if (s as usize) < n
                && member_mask[s as usize]
                && !seed_queue.contains(&s)
            {
                seed_queue.push(s);
            }
        }
        seed_queue.reverse(); // consumed by pop() in caller order

        let mut cand: Vec<u32> = members.clone();
        let mut lb = 0.0f32;
        let mut pick_hi = true;
        // (source, exact eccentricity) of every processed sweep.
        let mut processed: Vec<(u32, f32)> = Vec::new();

        while !cand.is_empty() && processed.len() < budget {
            // Assemble the round: landmarks first, then the serial
            // algorithm's alternating max-upper / max-lower picks. The
            // budget clamps the final round, never reorders it.
            let round = width.min(budget - processed.len());
            let mut batch: Vec<u32> = Vec::with_capacity(round);
            while batch.len() < round {
                let src = if let Some(s) = seed_queue.pop() {
                    match cand.iter().position(|&u| u == s) {
                        Some(i) => {
                            if let Some(c) = &self.obs_warm_hits {
                                c.fetch_add(1, Ordering::Relaxed);
                            }
                            cand.swap_remove(i)
                        }
                        None => continue, // already pruned
                    }
                } else if cand.is_empty() {
                    break;
                } else {
                    let (idx, _) = cand
                        .iter()
                        .enumerate()
                        .map(|(i, &u)| {
                            let score = if pick_hi {
                                ecc_hi[u as usize]
                            } else {
                                ecc_lo[u as usize]
                            };
                            (i, score)
                        })
                        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                        .unwrap();
                    pick_hi = !pick_hi;
                    cand.swap_remove(idx)
                };
                batch.push(src);
            }
            if batch.is_empty() {
                break;
            }
            if let Some(c) = &self.obs_sweeps {
                c.fetch_add(batch.len() as u64, Ordering::Relaxed);
            }

            // The round's SSSPs. Row i of `batch_dist` always belongs
            // to `batch[i]`, however the sweeps are distributed.
            let workers = self.threads.min(batch.len());
            if workers <= 1 {
                let mut sc = self.checkout();
                for (row, &src) in
                    batch_dist.chunks_mut(n).zip(batch.iter())
                {
                    csr.dijkstra_scratch(src as usize, row, &mut sc.heap);
                }
                self.checkin(sc);
            } else {
                let mut bins: Vec<Vec<(u32, &mut [f32])>> =
                    (0..workers).map(|_| Vec::new()).collect();
                for (i, (row, &src)) in batch_dist
                    .chunks_mut(n)
                    .zip(batch.iter())
                    .enumerate()
                {
                    bins[i % workers].push((src, row));
                }
                let csr_ref = &csr;
                let this = &*self;
                std::thread::scope(|scope| {
                    for bin in bins {
                        scope.spawn(move || {
                            let mut sc = this.checkout();
                            for (src, row) in bin {
                                csr_ref.dijkstra_scratch(
                                    src as usize,
                                    row,
                                    &mut sc.heap,
                                );
                            }
                            this.checkin(sc);
                        });
                    }
                });
            }

            // Sequential bound tightening, exactly the serial rule,
            // applied once per completed sweep.
            for (bi, &v) in batch.iter().enumerate() {
                let dist = &batch_dist[bi * n..(bi + 1) * n];
                let mut ecc_v = 0.0f32;
                for &u in &members {
                    let d = dist[u as usize];
                    if d.is_finite() && d > ecc_v {
                        ecc_v = d;
                    }
                }
                if ecc_v > lb {
                    lb = ecc_v;
                }
                // The swept source's eccentricity is exact; pin its
                // bounds so the upper envelope below sees it.
                ecc_lo[v as usize] = ecc_v;
                ecc_hi[v as usize] = ecc_v;
                processed.push((v, ecc_v));
                cand.retain(|&u| {
                    let u = u as usize;
                    let d = dist[u];
                    if d.is_finite() {
                        let lo = (ecc_v - d).max(d);
                        if lo > ecc_lo[u] {
                            ecc_lo[u] = lo;
                        }
                        let hi = ecc_v + d;
                        if hi < ecc_hi[u] {
                            ecc_hi[u] = hi;
                        }
                    }
                    if ecc_lo[u] > lb {
                        lb = ecc_lo[u];
                    }
                    ecc_hi[u] > lb + 1e-6
                });
            }
        }

        // Certified upper envelope: every member's eccentricity is
        // dominated by its `ecc_hi` (exact for swept sources), so the
        // max over members dominates the diameter. At convergence
        // every non-swept member was pruned at `<= lb + 1e-6`, so the
        // interval collapses.
        let mut ub = lb;
        for &u in &members {
            let hi = ecc_hi[u as usize];
            if hi > ub {
                ub = hi;
            }
        }
        let sweeps = processed.len();

        // Keep the far-out sources as next-call landmarks.
        processed.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        processed.truncate(MAX_LANDMARKS);
        let landmarks = processed.into_iter().map(|(v, _)| v).collect();
        self.checkin_arena(ar);
        DiameterEst {
            lower: lb,
            upper: ub,
            landmarks,
            sweeps,
        }
    }

    /// Diameter of every graph in a candidate population, one task per
    /// graph across the pool. Values are identical to calling
    /// [`super::diameter::diameter`] per graph (each task IS that call).
    pub fn diameter_batch(&self, gs: &[Graph]) -> Vec<f32> {
        if self.threads <= 1 || gs.len() <= 1 {
            return gs.iter().map(diameter::diameter).collect();
        }
        let idx: Vec<usize> = (0..gs.len()).collect();
        crate::par::scoped_map(idx, self.threads, |_, i| {
            diameter::diameter(&gs[i])
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::apsp;
    use crate::latency::Model;
    use crate::topology::{kring, paper_k};
    use crate::util::rng::Rng;

    fn overlay(n: usize, seed: u64) -> Graph {
        let mut rng = Rng::new(seed);
        let w = Model::Uniform.sample(n, &mut rng);
        kring::random_krings(n, paper_k(n), &mut rng).to_graph(&w)
    }

    #[test]
    fn apsp_par_matches_serial_bitwise() {
        let g = overlay(48, 0xE7A1);
        let serial = apsp::apsp(&g);
        for threads in [1, 2, 3, 8] {
            let pool = EvalPool::new(threads);
            let par = pool.apsp_par(&g);
            assert_eq!(serial.n, par.n);
            for (a, b) in serial.d.iter().zip(&par.d) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}");
            }
        }
    }

    #[test]
    fn diameter_par_matches_serial() {
        for trial in 0..6 {
            let n = 16 + 11 * trial;
            let g = overlay(n, 0xD1A + trial as u64);
            let serial = diameter::diameter(&g);
            for threads in [1, 2, 8] {
                let pool = EvalPool::new(threads);
                let par = pool.diameter_par(&g);
                assert!(
                    (par - serial).abs() <= 1e-3 * serial.max(1.0),
                    "n={n} threads={threads}: {par} vs {serial}"
                );
            }
        }
    }

    #[test]
    fn warm_seeds_do_not_change_the_value() {
        let g = overlay(40, 7);
        let serial = diameter::diameter(&g);
        let pool = EvalPool::new(4);
        let (d0, landmarks) = pool.diameter_with_seeds(&g, &[]);
        assert!((d0 - serial).abs() <= 1e-3 * serial.max(1.0));
        assert!(!landmarks.is_empty() && landmarks.len() <= MAX_LANDMARKS);
        // Re-certify from the landmarks (the scenario engine's pattern),
        // and from garbage seeds including out-of-range ids.
        let (d1, _) = pool.diameter_with_seeds(&g, &landmarks);
        assert!((d1 - serial).abs() <= 1e-3 * serial.max(1.0));
        let (d2, _) = pool.diameter_with_seeds(&g, &[0, 0, 39, 1000]);
        assert!((d2 - serial).abs() <= 1e-3 * serial.max(1.0));
    }

    #[test]
    fn diameter_est_brackets_and_converges() {
        for trial in 0..4 {
            let n = 24 + 17 * trial;
            let g = overlay(n, 0xE57 + trial as u64);
            let exact = diameter::diameter(&g);
            let pool = EvalPool::new(4);
            let mut prev_gap = f32::INFINITY;
            for budget in [1, 4, 16, 4096] {
                let est = pool.diameter_est(&g, &[], budget);
                assert!(
                    est.lower <= exact + 1e-3 * exact.max(1.0)
                        && exact <= est.upper + 1e-3 * exact.max(1.0),
                    "n={n} budget={budget}: [{}, {}] vs {exact}",
                    est.lower,
                    est.upper
                );
                assert!(est.sweeps <= budget);
                assert!(est.lower <= est.upper);
                // More budget never loosens the certified width by
                // more than fp noise (the schedule prefix is shared).
                let gap = est.upper - est.lower;
                assert!(gap <= prev_gap + 1e-4, "budget={budget}");
                prev_gap = gap;
            }
            // A generous budget converges to the exact value.
            let est = pool.diameter_est(&g, &[], 4096);
            assert!(est.upper - est.lower <= 1e-5);
            assert!((est.lower - exact).abs() <= 1e-3 * exact.max(1.0));
        }
    }

    #[test]
    fn diameter_est_is_thread_invariant() {
        let g = overlay(64, 0xBEEF);
        let reference = EvalPool::new(1).diameter_est(&g, &[], 8);
        for threads in [2, 8] {
            let est = EvalPool::new(threads).diameter_est(&g, &[], 8);
            assert_eq!(est.lower.to_bits(), reference.lower.to_bits());
            assert_eq!(est.upper.to_bits(), reference.upper.to_bits());
            assert_eq!(est.landmarks, reference.landmarks);
            assert_eq!(est.sweeps, reference.sweeps);
        }
    }

    #[test]
    fn certify_config_parses_and_validates() {
        assert_eq!(CertifyMode::parse("exact"), Some(CertifyMode::Exact));
        assert_eq!(CertifyMode::parse("hybrid"), Some(CertifyMode::Hybrid));
        assert_eq!(CertifyMode::parse("sketch"), Some(CertifyMode::Sketch));
        assert_eq!(CertifyMode::parse("bogus"), None);
        let modes =
            [CertifyMode::Exact, CertifyMode::Hybrid, CertifyMode::Sketch];
        for m in modes {
            assert_eq!(CertifyMode::parse(m.name()), Some(m));
        }
        let mut c = CertifyConfig::exact();
        assert!(c.validate().is_ok() && c.is_exact());
        c.mode = CertifyMode::Hybrid;
        c.oracle_every = 3;
        assert!(c.oracle_period(0) && !c.oracle_period(1));
        assert!(c.oracle_period(3) && !c.oracle_period(4));
        c.budget = 0;
        assert!(c.validate().is_err());
        c.budget = 4;
        c.oracle_every = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn diameter_batch_matches_per_graph_serial() {
        let gs: Vec<Graph> =
            (0..7).map(|i| overlay(20 + i, 100 + i as u64)).collect();
        let serial: Vec<f32> =
            gs.iter().map(diameter::diameter).collect();
        for threads in [1, 2, 8] {
            let pool = EvalPool::new(threads);
            assert_eq!(pool.diameter_batch(&gs), serial);
        }
    }

    #[test]
    fn degenerate_graphs() {
        let pool = EvalPool::new(4);
        let empty = Graph::empty(0);
        assert_eq!(pool.apsp_par(&empty).d.len(), 0);
        assert_eq!(pool.diameter_par(&empty), 0.0);
        let edgeless = Graph::empty(5);
        assert_eq!(pool.diameter_par(&edgeless), 0.0);
        assert_eq!(pool.diameter_with_seeds(&edgeless, &[1, 2]).0, 0.0);
        assert!(pool.diameter_batch(&[]).is_empty());
        let est = pool.diameter_est(&edgeless, &[], 4);
        assert_eq!((est.lower, est.upper), (0.0, 0.0));
        assert_eq!(est.gap_pct(), 0.0);
        // Disconnected: largest component rules, same as serial.
        let g = Graph::from_weighted_edges(
            6,
            &[(0, 1, 1.0), (1, 2, 1.0), (3, 4, 9.0)],
        );
        assert_eq!(pool.diameter_par(&g), diameter::diameter(&g));
    }
}
