//! Typed configuration for the coordinator and the figure harness.
//!
//! Config files are JSON (parsed by the in-tree [`crate::util::json`]);
//! every field has a default so an empty object is a valid config, and
//! unknown keys are rejected (catches typos in experiment scripts).

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

/// Full runtime configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct Config {
    /// Overlay size (number of controller nodes).
    pub nodes: usize,
    /// Latency model name (uniform | gaussian | fabric | bitnode).
    pub model: String,
    /// Rings per overlay (0 = paper default log2 N).
    pub k: usize,
    /// RNG seed.
    pub seed: u64,
    /// ρ-band half width for adaptive selection.
    pub epsilon: f64,
    /// Gossip measurement samples per node (Algorithm 3's K).
    pub gossip_samples: usize,
    /// Gossip rounds per measurement period.
    pub gossip_rounds: usize,
    /// Partitions for parallel construction (1 = sequential).
    pub partitions: usize,
    /// Worker threads.
    pub threads: usize,
    /// Artifact directory for the PJRT Q-net.
    pub artifacts_dir: String,
    /// Scorer backend: pjrt | native | greedy.
    pub scorer: String,
    /// Mean per-node processing delay Δ_v in ms (paper: 1 ms).
    pub proc_delay_ms: f64,
    /// Coordinator: re-measure / adapt every this many sim-ms.
    pub adapt_period_ms: f64,
    /// Churn-aware ρ guard: when more than this many membership events
    /// land in one adaptation period, the coordinator skips the ring
    /// swap for that period (re-anchoring during a storm it cannot win
    /// just burns churn). 0 disables the guard.
    pub churn_guard: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            nodes: 100,
            model: "uniform".to_string(),
            k: 0,
            seed: 7,
            epsilon: 0.25,
            gossip_samples: 4,
            gossip_rounds: 20,
            partitions: 1,
            threads: 1,
            artifacts_dir: "artifacts".to_string(),
            scorer: "native".to_string(),
            proc_delay_ms: 1.0,
            adapt_period_ms: 500.0,
            churn_guard: 0,
        }
    }
}

impl Config {
    /// Parse from JSON text, rejecting unknown keys.
    pub fn parse(text: &str) -> Result<Config> {
        let root = json::parse(text).context("parsing config JSON")?;
        let obj = root.as_obj()?;
        let mut cfg = Config::default();
        for (key, val) in obj {
            match key.as_str() {
                "nodes" => cfg.nodes = val.as_usize()?,
                "model" => cfg.model = val.as_str()?.to_string(),
                "k" => cfg.k = val.as_usize()?,
                "seed" => cfg.seed = val.as_f64()? as u64,
                "epsilon" => cfg.epsilon = val.as_f64()?,
                "gossip_samples" => cfg.gossip_samples = val.as_usize()?,
                "gossip_rounds" => cfg.gossip_rounds = val.as_usize()?,
                "partitions" => cfg.partitions = val.as_usize()?,
                "threads" => cfg.threads = val.as_usize()?,
                "artifacts_dir" => {
                    cfg.artifacts_dir = val.as_str()?.to_string()
                }
                "scorer" => cfg.scorer = val.as_str()?.to_string(),
                "proc_delay_ms" => cfg.proc_delay_ms = val.as_f64()?,
                "adapt_period_ms" => cfg.adapt_period_ms = val.as_f64()?,
                "churn_guard" => {
                    cfg.churn_guard = val.as_f64()? as u64
                }
                other => bail!("unknown config key '{other}'"),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load a config from a JSON file (unknown keys rejected).
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Config> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {:?}", path.as_ref()))?;
        Config::parse(&text)
    }

    /// Effective K (paper default when k == 0).
    pub fn effective_k(&self) -> usize {
        if self.k == 0 {
            crate::topology::paper_k(self.nodes)
        } else {
            self.k
        }
    }

    /// Check cross-field invariants (sizes, rates, known names).
    pub fn validate(&self) -> Result<()> {
        if self.nodes < 3 {
            bail!("nodes must be >= 3, got {}", self.nodes);
        }
        if crate::latency::Model::parse(&self.model).is_none() {
            bail!("unknown latency model '{}'", self.model);
        }
        if !(0.0..0.5).contains(&self.epsilon) {
            bail!("epsilon must be in [0, 0.5), got {}", self.epsilon);
        }
        if self.partitions == 0 || self.partitions > self.nodes {
            bail!(
                "partitions must be in 1..=nodes, got {}",
                self.partitions
            );
        }
        if !matches!(self.scorer.as_str(), "pjrt" | "native" | "greedy") {
            bail!("scorer must be pjrt|native|greedy, got '{}'", self.scorer);
        }
        Ok(())
    }

    /// Serialize (for `dgro config --print` and test round-trips).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("nodes", Json::num(self.nodes as f64)),
            ("model", Json::str(self.model.clone())),
            ("k", Json::num(self.k as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("epsilon", Json::num(self.epsilon)),
            ("gossip_samples", Json::num(self.gossip_samples as f64)),
            ("gossip_rounds", Json::num(self.gossip_rounds as f64)),
            ("partitions", Json::num(self.partitions as f64)),
            ("threads", Json::num(self.threads as f64)),
            ("artifacts_dir", Json::str(self.artifacts_dir.clone())),
            ("scorer", Json::str(self.scorer.clone())),
            ("proc_delay_ms", Json::num(self.proc_delay_ms)),
            ("adapt_period_ms", Json::num(self.adapt_period_ms)),
            ("churn_guard", Json::num(self.churn_guard as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_object_is_default() {
        let cfg = Config::parse("{}").unwrap();
        assert_eq!(cfg, Config::default());
    }

    #[test]
    fn overrides_apply() {
        let cfg = Config::parse(
            r#"{"nodes": 64, "model": "fabric", "scorer": "greedy"}"#,
        )
        .unwrap();
        assert_eq!(cfg.nodes, 64);
        assert_eq!(cfg.model, "fabric");
        assert_eq!(cfg.effective_k(), 6);
    }

    #[test]
    fn unknown_key_rejected() {
        let err = Config::parse(r#"{"nodez": 64}"#).unwrap_err();
        assert!(err.to_string().contains("nodez"));
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(Config::parse(r#"{"nodes": 2}"#).is_err());
        assert!(Config::parse(r#"{"model": "marsnet"}"#).is_err());
        assert!(Config::parse(r#"{"epsilon": 0.7}"#).is_err());
        assert!(Config::parse(r#"{"scorer": "gpt"}"#).is_err());
        assert!(Config::parse(r#"{"partitions": 0}"#).is_err());
    }

    #[test]
    fn json_roundtrip() {
        let mut cfg = Config::default();
        cfg.nodes = 42;
        cfg.model = "bitnode".into();
        let text = cfg.to_json().to_string();
        let back = Config::parse(&text).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn effective_k_explicit_wins() {
        let cfg = Config::parse(r#"{"nodes": 64, "k": 3}"#).unwrap();
        assert_eq!(cfg.effective_k(), 3);
    }
}
