//! §V — Self-adaptive ring topology selection.
//!
//! After a gossip measurement period, each node evaluates
//! ρ = (L̄_local − L̄_min) / (L̄_global − L̄_min):
//!
//! * ρ ≤ ε      — neighbors are essentially the nearest nodes: the
//!                topology is **too clustered** (Perigee-like); add or
//!                swap in a **random ring** to cut long chains.
//! * ρ ≥ 1 − ε  — neighbors look like uniform random picks: the
//!                topology is **too dispersed** (Chord/RAPID-like); add
//!                or swap in the **shortest ring** to exploit locality.
//! * otherwise  — keep the current mix.
//!
//! (The paper's prose has a typo assigning both conditions to "ρ > ε";
//! the directions above follow its own examples: "Chord shows a ρ close
//! to 1. By replacing the random ring with the shortest ring, the
//! diameter is reduced by 10-40%", and Perigee with ρ ≈ 0 benefits from
//! the random ring.)

use crate::gossip::measure::GossipStats;
use crate::graph::ring::Ring;
use crate::latency::LatencyMatrix;
use crate::topology::{random_ring, shortest_ring};
use crate::util::rng::Rng;

/// The adaptive decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RingChoice {
    /// Topology too clustered — introduce a random ring.
    Random,
    /// Topology too dispersed — introduce the shortest ring.
    Shortest,
    /// Within the balanced band — leave as is.
    Keep,
}

#[derive(Clone, Copy, Debug)]
/// Knobs of the SS-V decision rule.
pub struct SelectConfig {
    /// The ε band half-width.
    pub epsilon: f64,
}

impl Default for SelectConfig {
    fn default() -> Self {
        SelectConfig { epsilon: 0.25 }
    }
}

/// Apply the §V decision rule to a measured ρ.
pub fn decide(stats: &GossipStats, cfg: SelectConfig) -> RingChoice {
    let rho = stats.rho();
    if rho <= cfg.epsilon {
        RingChoice::Random
    } else if rho >= 1.0 - cfg.epsilon {
        RingChoice::Shortest
    } else {
        RingChoice::Keep
    }
}

/// Materialize a decision into a ring (None for Keep). `start` seeds the
/// shortest ring; the random ring draws from `rng`.
pub fn materialize(
    choice: RingChoice,
    w: &LatencyMatrix,
    start: usize,
    rng: &mut Rng,
) -> Option<Ring> {
    match choice {
        RingChoice::Random => Some(random_ring(w.n(), rng)),
        RingChoice::Shortest => Some(shortest_ring(w, start)),
        RingChoice::Keep => None,
    }
}

/// The full §V loop as a one-shot builder — the "DGRO" line of Figs 1,
/// 13 and 17: start from the K random rings consistent hashing gives
/// every deployed system, then repeatedly measure ρ by gossip and swap
/// one ring toward the decision until the band says Keep (at most K
/// swaps — bounded churn).
pub fn adaptive_krings(
    w: &LatencyMatrix,
    k: usize,
    rng: &mut Rng,
) -> crate::topology::kring::KRing {
    use crate::gossip::measure::{measure, MeasureConfig};
    let n = w.n();
    let mut kr = crate::topology::kring::random_krings(n, k, rng);
    let mut n_short = 0usize;
    for _ in 0..k {
        let g = kr.to_graph(w);
        let stats = measure(w, &g, MeasureConfig::default(), rng);
        match decide(&stats, SelectConfig::default()) {
            RingChoice::Keep => break,
            RingChoice::Shortest if n_short < k => {
                // Rings [0..n_short) hold shortest rings, each anchored
                // at a spread-out start node.
                let start = (n_short * n) / k.max(1) % n;
                kr.replace(n_short, shortest_ring(w, start));
                n_short += 1;
            }
            RingChoice::Random if n_short > 0 => {
                n_short -= 1;
                kr.replace(n_short, random_ring(n, rng));
            }
            _ => break, // saturated in the decision's direction
        }
    }
    kr
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gossip::measure::{measure, MeasureConfig};
    use crate::latency::fabric;
    use crate::topology::random_ring as rr;

    fn stats(local: f64, global: f64, min: f64) -> GossipStats {
        GossipStats {
            local,
            global,
            min,
            messages: 0,
        }
    }

    #[test]
    fn decision_bands() {
        let cfg = SelectConfig { epsilon: 0.25 };
        // rho = 0 -> clustered -> Random.
        assert_eq!(decide(&stats(1.0, 10.0, 1.0), cfg), RingChoice::Random);
        // rho = 1 -> dispersed -> Shortest.
        assert_eq!(decide(&stats(10.0, 10.0, 1.0), cfg), RingChoice::Shortest);
        // rho = 0.5 -> Keep.
        assert_eq!(decide(&stats(5.5, 10.0, 1.0), cfg), RingChoice::Keep);
    }

    #[test]
    fn chord_like_overlay_gets_shortest_ring() {
        // End-to-end: random ring on clustered latencies -> Shortest.
        let mut rng = Rng::new(1);
        let w = fabric::sample(68, &mut rng);
        let g = rr(68, &mut rng).to_graph(&w);
        let st = measure(&w, &g, MeasureConfig::default(), &mut rng);
        assert_eq!(
            decide(&st, SelectConfig::default()),
            RingChoice::Shortest,
            "rho = {}",
            st.rho()
        );
    }

    #[test]
    fn perigee_like_overlay_gets_random_ring() {
        let mut rng = Rng::new(2);
        let w = fabric::sample(68, &mut rng);
        let g = crate::topology::shortest_ring(&w, 0).to_graph(&w);
        let st = measure(&w, &g, MeasureConfig::default(), &mut rng);
        assert_eq!(
            decide(&st, SelectConfig::default()),
            RingChoice::Random,
            "rho = {}",
            st.rho()
        );
    }

    #[test]
    fn materialize_produces_valid_rings() {
        let mut rng = Rng::new(3);
        let w = fabric::sample(30, &mut rng);
        let r = materialize(RingChoice::Random, &w, 0, &mut rng).unwrap();
        r.validate().unwrap();
        let s = materialize(RingChoice::Shortest, &w, 3, &mut rng).unwrap();
        s.validate().unwrap();
        assert_eq!(s.order()[0], 3);
        assert!(materialize(RingChoice::Keep, &w, 0, &mut rng).is_none());
    }
}
