//! Algorithm 4 — Parallel Ring Construction (paper §VI).
//!
//! The N nodes are segmented into M partitions by striding a base random
//! ring (§VII-C4: "a random ring is initially segmented into M
//! partitions using a same stride, with each partition's starting node
//! determined by a consistent hash function"). Each partition reorders
//! its interior concurrently with DGRO (any scorer backend), then the
//! segments are stitched: the last node of partition i connects to the
//! first node of partition i+1, closing the global ring. N sequential
//! steps become N/M per worker.

use anyhow::Result;

use crate::graph::ring::Ring;
use crate::latency::LatencyMatrix;
use crate::par::scoped_map;
use crate::qnet::state::State;
use crate::qnet::QScorer;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
/// Knobs of Algorithm 4.
pub struct ParallelConfig {
    /// Number of partitions M.
    pub partitions: usize,
    /// OS threads to run partition builds on (≤ M; defaults to M).
    pub threads: usize,
}

impl ParallelConfig {
    /// M partitions, one thread each.
    pub fn new(partitions: usize) -> ParallelConfig {
        ParallelConfig {
            partitions,
            threads: partitions,
        }
    }
}

/// Split a base permutation into M contiguous segments (sizes differ by
/// at most 1 — Algorithm 4's "remaining nodes" are folded into the last
/// partitions rather than appended unordered).
pub fn partition(base: &[u32], m: usize) -> Vec<Vec<u32>> {
    let n = base.len();
    assert!(m >= 1 && m <= n, "need 1 <= M <= N, got M={m}, N={n}");
    let size = n / m;
    let extra = n % m;
    let mut out = Vec::with_capacity(m);
    let mut pos = 0;
    for i in 0..m {
        let len = size + usize::from(i < extra);
        out.push(base[pos..pos + len].to_vec());
        pos += len;
    }
    debug_assert_eq!(pos, n);
    out
}

/// Order one partition's nodes as a path with Algorithm 1 restricted to
/// the partition (sub-matrix of W), starting from the partition's first
/// node (its consistent-hash anchor).
fn order_partition(
    scorer: &mut dyn QScorer,
    w: &LatencyMatrix,
    members: &[u32],
) -> Result<Vec<u32>> {
    let k = members.len();
    if k <= 2 {
        return Ok(members.to_vec());
    }
    // Sub-latency-matrix over the partition members.
    let sub = LatencyMatrix::from_fn(k, |a, b| {
        w.get(members[a] as usize, members[b] as usize)
    });
    let mut st = State::new(&sub, 0);
    let mut order = vec![members[0]];
    while !st.done() {
        let q = scorer.score(&st)?;
        let next = st.argmax_unvisited(&q).expect("unvisited remain");
        st.step(next);
        order.push(members[next]);
    }
    Ok(order)
}

/// Build a ring over all N nodes with M-way parallel construction.
///
/// `base` is the pre-partitioning random ring (consistent-hash order);
/// `make_scorer` constructs a per-worker scorer (scorers are stateful
/// and not shareable across threads).
pub fn parallel_ring<F>(
    w: &LatencyMatrix,
    base: &Ring,
    cfg: ParallelConfig,
    make_scorer: F,
) -> Result<Ring>
where
    F: Fn(usize) -> Box<dyn QScorer> + Sync,
{
    let parts = partition(base.order(), cfg.partitions);
    let threads = cfg.threads.clamp(1, cfg.partitions);
    let ordered: Vec<Result<Vec<u32>>> =
        scoped_map(parts, threads, |idx, members| {
            let mut scorer = make_scorer(idx);
            order_partition(scorer.as_mut(), w, &members)
        });
    let mut order = Vec::with_capacity(base.n());
    for seg in ordered {
        order.extend(seg?);
    }
    Ring::new(order)
}

/// Convenience: random base ring from a seed, greedy scorer per worker.
pub fn parallel_ring_greedy(
    w: &LatencyMatrix,
    cfg: ParallelConfig,
    rng: &mut Rng,
) -> Result<Ring> {
    let base = crate::topology::random_ring(w.n(), rng);
    parallel_ring(w, &base, cfg, |_| {
        Box::new(super::construct::GreedyScorer)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dgro::construct::GreedyScorer;
    use crate::graph::diameter;
    use crate::latency::{synthetic, LatencyMatrix};

    #[test]
    fn partition_sizes_balanced() {
        let base: Vec<u32> = (0..10).collect();
        let parts = partition(&base, 3);
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[0].len(), 4);
        assert_eq!(parts[1].len(), 3);
        assert_eq!(parts[2].len(), 3);
        let flat: Vec<u32> = parts.concat();
        assert_eq!(flat, base);
    }

    #[test]
    fn parallel_ring_is_valid_permutation() {
        let mut rng = Rng::new(1);
        let w = synthetic::uniform(40, &mut rng);
        for m in [1usize, 2, 4, 8] {
            let ring =
                parallel_ring_greedy(&w, ParallelConfig::new(m), &mut rng)
                    .unwrap();
            ring.validate().unwrap();
            assert_eq!(ring.n(), 40);
        }
    }

    #[test]
    fn single_partition_equals_sequential() {
        // Tie-free metric (distinct pairwise latencies) so greedy
        // tie-breaking cannot differ between index orders.
        let mut rng = Rng::new(2);
        let w = LatencyMatrix::from_fn(20, |u, v| {
            ((u * 31 + v * 17 + u * v) % 97 + 1) as f32
                + (u + v) as f32 * 0.001
        });
        let base = crate::topology::random_ring(20, &mut rng);
        let par = parallel_ring(
            &w,
            &base,
            ParallelConfig::new(1),
            |_| Box::new(GreedyScorer),
        )
        .unwrap();
        // M=1: one partition holding the whole base ring, ordered from
        // base.order()[0] — identical to a sequential greedy build from
        // that start.
        let seq = crate::topology::shortest_ring(
            &w,
            base.order()[0] as usize,
        );
        assert_eq!(par.order(), seq.order());
    }

    #[test]
    fn parallel_diameter_stays_close_to_sequential() {
        // The paper's §VI claim, miniature: partitioned construction
        // should not blow up the diameter. Allow a generous factor; the
        // figure harness (fig14/fig18) measures the real curves.
        let mut rng = Rng::new(3);
        let w = synthetic::uniform(64, &mut rng);
        let k = 2;
        let seq = {
            let mut scorer = GreedyScorer;
            let (_, g) = crate::dgro::construct::build_kring(
                &mut scorer,
                &w,
                k,
                &[0, 32],
            )
            .unwrap();
            diameter::diameter(&g)
        };
        let par_d = {
            let r1 = parallel_ring_greedy(
                &w,
                ParallelConfig::new(8),
                &mut rng,
            )
            .unwrap();
            let r2 = parallel_ring_greedy(
                &w,
                ParallelConfig::new(8),
                &mut rng,
            )
            .unwrap();
            let g = crate::topology::kring::KRing::new(vec![r1, r2])
                .to_graph(&w);
            diameter::diameter(&g)
        };
        assert!(
            par_d <= seq * 2.0,
            "parallel {par_d} vs sequential {seq}"
        );
    }

    #[test]
    #[should_panic(expected = "1 <= M <= N")]
    fn rejects_more_partitions_than_nodes() {
        let base: Vec<u32> = (0..4).collect();
        let _ = partition(&base, 5);
    }
}
