//! Algorithm 1 — Diameter-Guided Ring Construction.
//!
//! From a start node, repeatedly pick the unvisited node with the
//! highest Q-value (any [`QScorer`] backend: the PJRT artifact, the
//! native mirror, or the nearest-neighbour [`GreedyScorer`]), then close
//! the ring. K-ring construction accumulates the adjacency across rings
//! so later rings see the existing topology (paper §IV-B/§IV-C: the
//! state is "the latency matrix in conjunction with the topology that
//! has been constructed up to the current step").

use anyhow::Result;

use crate::graph::ring::Ring;
use crate::graph::{diameter, Graph};
use crate::latency::LatencyMatrix;
use crate::qnet::state::State;
use crate::qnet::QScorer;
use crate::util::rng::Rng;

/// Nearest-neighbour scorer through the QScorer interface: score(u) =
/// −w(v_t, u). Lets the heuristic share every construction/bench path
/// with the learned scorers.
pub struct GreedyScorer;

impl QScorer for GreedyScorer {
    fn score(&mut self, st: &State) -> Result<Vec<f32>> {
        let row = st.w.row(st.cur);
        Ok(row.iter().map(|&w| -w).collect())
    }

    fn name(&self) -> &'static str {
        "greedy-nn"
    }
}

/// Build one ring with Algorithm 1 starting at `start`, given an
/// existing construction state (callers building K rings pass the
/// accumulated state; fresh callers use [`build_ring`]).
pub fn build_ring_from_state(
    scorer: &mut dyn QScorer,
    st: &mut State,
    start: usize,
) -> Result<Ring> {
    let n = st.n;
    let mut order = Vec::with_capacity(n);
    order.push(start as u32);
    while !st.done() {
        let q = scorer.score(st)?;
        let next = st
            .argmax_unvisited(&q)
            .expect("unvisited nodes remain");
        st.step(next);
        order.push(next as u32);
    }
    st.close(start);
    Ring::new(order)
}

/// Build a single ring over `w` starting at `start`.
pub fn build_ring(
    scorer: &mut dyn QScorer,
    w: &LatencyMatrix,
    start: usize,
) -> Result<Ring> {
    let mut st = State::new(w, start);
    build_ring_from_state(scorer, &mut st, start)
}

/// Build K rings, each seeing the topology accumulated so far. Returns
/// the rings and the final overlay graph.
pub fn build_kring(
    scorer: &mut dyn QScorer,
    w: &LatencyMatrix,
    k: usize,
    starts: &[usize],
) -> Result<(Vec<Ring>, Graph)> {
    assert_eq!(starts.len(), k, "one start node per ring");
    let n = w.n();
    let mut rings = Vec::with_capacity(k);
    let mut st = State::new(w, starts[0]);
    for (i, &start) in starts.iter().enumerate() {
        if i > 0 {
            st = st.with_cursor(start);
        }
        rings.push(build_ring_from_state(scorer, &mut st, start)?);
    }
    let mut g = Graph::empty(n);
    for ring in &rings {
        for (u, v) in ring.edges() {
            g.add_edge(u as usize, v as usize, w.get(u as usize, v as usize));
        }
    }
    Ok((rings, g))
}

/// §VII-B2: construct `n_starts` K-ring topologies from random distinct
/// start sets and keep the one with the smallest diameter.
pub fn best_of_starts(
    scorer: &mut dyn QScorer,
    w: &LatencyMatrix,
    k: usize,
    n_starts: usize,
    rng: &mut Rng,
) -> Result<(Vec<Ring>, Graph, f32)> {
    assert!(n_starts > 0);
    let n = w.n();
    let mut best: Option<(Vec<Ring>, Graph, f32)> = None;
    for _ in 0..n_starts {
        let starts: Vec<usize> =
            (0..k).map(|_| rng.index(n)).collect();
        let (rings, g) = build_kring(scorer, w, k, &starts)?;
        let d = diameter::diameter(&g);
        if best.as_ref().map_or(true, |(_, _, bd)| d < *bd) {
            best = Some((rings, g, d));
        }
    }
    Ok(best.unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::components;
    use crate::latency::synthetic;
    use crate::qnet::native::NativeQnet;
    use crate::qnet::params::QnetParams;

    #[test]
    fn greedy_build_matches_shortest_ring() {
        let mut rng = Rng::new(1);
        let w = synthetic::uniform(18, &mut rng);
        let mut scorer = GreedyScorer;
        let ring = build_ring(&mut scorer, &w, 4).unwrap();
        let nn = crate::topology::shortest_ring(&w, 4);
        assert_eq!(ring.order(), nn.order(),
            "greedy-through-Algorithm-1 must equal the NN heuristic");
    }

    #[test]
    fn build_ring_valid_with_native_qnet() {
        let mut rng = Rng::new(2);
        let w = synthetic::uniform(16, &mut rng);
        let mut scorer = NativeQnet::new(QnetParams::synthetic(16, 32, 7));
        let ring = build_ring(&mut scorer, &w, 0).unwrap();
        ring.validate().unwrap();
        assert_eq!(ring.order()[0], 0);
    }

    #[test]
    fn kring_accumulates_and_connects() {
        let mut rng = Rng::new(3);
        let w = synthetic::uniform(14, &mut rng);
        let mut scorer = GreedyScorer;
        let (rings, g) = build_kring(&mut scorer, &w, 3, &[0, 5, 9]).unwrap();
        assert_eq!(rings.len(), 3);
        rings.iter().for_each(|r| r.validate().unwrap());
        assert!(components::is_connected(&g));
        assert!(g.max_degree() <= 6);
        // Second/third rings saw the first ring's adjacency, so they are
        // typically NOT identical to a fresh greedy ring — just validate
        // the union's degree/connectivity invariants hold.
    }

    #[test]
    fn best_of_starts_is_min_over_runs() {
        let mut rng = Rng::new(4);
        let w = synthetic::uniform(15, &mut rng);
        let mut scorer = GreedyScorer;
        let (_, _, best_d) =
            best_of_starts(&mut scorer, &w, 2, 6, &mut rng).unwrap();
        // Must be at least as good as one specific single-start run.
        let (_, g1) = build_kring(&mut scorer, &w, 2, &[0, 0]).unwrap();
        let d1 = diameter::diameter(&g1);
        assert!(best_d <= d1 + 1e-6, "{best_d} vs single-start {d1}");
    }
}
