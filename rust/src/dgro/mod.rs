//! DGRO proper — the paper's contribution, assembled from three parts:
//!
//! * [`construct`] — Algorithm 1: greedy-over-Q ring construction, plus
//!   multi-start selection (§VII-B2: 10 starts, keep the best diameter)
//!   and K-ring accumulation (§IV-B).
//! * [`parallel`]  — Algorithm 4 (§VI): M-partition concurrent
//!   construction with segment stitching.
//! * [`select`]    — §V: the ρ-statistic adaptive ring selection driven
//!   by gossip-measured latencies (Algorithm 3 lives in
//!   [`crate::gossip`]).

pub mod construct;
pub mod parallel;
pub mod select;

pub use construct::{best_of_starts, build_kring, build_ring, GreedyScorer};
pub use parallel::{parallel_ring, ParallelConfig};
pub use select::{decide, RingChoice, SelectConfig};
