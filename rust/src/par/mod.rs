//! Thread-pool substrate (no rayon/tokio offline — DESIGN.md §3).
//!
//! Two primitives cover everything the coordinator and the parallel ring
//! builder (paper §VI, Algorithm 4) need:
//!   * [`ThreadPool`] — long-lived workers consuming boxed jobs.
//!   * [`scoped_map`] — fork-join: apply a closure to every item of a
//!     slice on `threads` OS threads and collect results in order.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size pool of worker threads executing boxed closures.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    sender: Option<mpsc::Sender<Job>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> ThreadPool {
        assert!(threads > 0);
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(receiver));
        let workers = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&receiver);
                thread::spawn(move || loop {
                    let job = { rx.lock().unwrap().recv() };
                    match job {
                        Ok(job) => job(),
                        Err(_) => break, // channel closed -> shut down
                    }
                })
            })
            .collect();
        ThreadPool {
            workers,
            sender: Some(sender),
        }
    }

    /// Submit a job; runs as soon as a worker frees up.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.sender
            .as_ref()
            .expect("pool alive")
            .send(Box::new(job))
            .expect("workers alive");
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.sender.take()); // close the channel
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Fork-join map: apply `f` to every element of `items` using up to
/// `threads` OS threads; results come back in input order. Panics in `f`
/// propagate. Items and results cross thread boundaries by value.
pub fn scoped_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    assert!(threads > 0);
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.min(n);
    let work: Mutex<Vec<Option<(usize, T)>>> = Mutex::new(
        items.into_iter().enumerate().map(Some).rev().collect(),
    );
    let results: Mutex<Vec<Option<R>>> =
        Mutex::new((0..n).map(|_| None).collect());
    let fref = &f;
    let wref = &work;
    let rref = &results;
    thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(move || loop {
                let item = { wref.lock().unwrap().pop() };
                match item {
                    Some(Some((idx, item))) => {
                        let out = fref(idx, item);
                        rref.lock().unwrap()[idx] = Some(out);
                    }
                    _ => break,
                }
            });
        }
    });
    results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("all work completed"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scoped_map_preserves_order() {
        let items: Vec<usize> = (0..97).collect();
        let out = scoped_map(items, 8, |idx, x| {
            assert_eq!(idx, x);
            x * x
        });
        assert_eq!(out, (0..97).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_map_empty() {
        let out: Vec<u32> = scoped_map(Vec::<u32>::new(), 4, |_, x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn scoped_map_single_thread() {
        let out = scoped_map(vec![1, 2, 3], 1, |_, x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn scoped_map_more_threads_than_items() {
        let out = scoped_map(vec![5], 16, |_, x| x * 2);
        assert_eq!(out, vec![10]);
    }
}
