//! Thread-pool substrate (no rayon/tokio offline — DESIGN.md §3).
//!
//! Two primitives cover everything the coordinator and the parallel ring
//! builder (paper §VI, Algorithm 4) need:
//!   * [`ThreadPool`] — long-lived workers consuming boxed jobs.
//!   * [`scoped_map`] — fork-join: apply a closure to every item of a
//!     slice on `threads` OS threads and collect results in order.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size pool of worker threads executing boxed closures.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    sender: Option<mpsc::Sender<Job>>,
}

impl ThreadPool {
    /// A pool of `threads` workers (panics on 0).
    pub fn new(threads: usize) -> ThreadPool {
        assert!(threads > 0);
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(receiver));
        let workers = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&receiver);
                thread::spawn(move || loop {
                    let job = { rx.lock().unwrap().recv() };
                    match job {
                        Ok(job) => job(),
                        Err(_) => break, // channel closed -> shut down
                    }
                })
            })
            .collect();
        ThreadPool {
            workers,
            sender: Some(sender),
        }
    }

    /// Submit a job; runs as soon as a worker frees up.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.sender
            .as_ref()
            .expect("pool alive")
            .send(Box::new(job))
            .expect("workers alive");
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.sender.take()); // close the channel
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Fork-join map: apply `f` to every element of `items` using up to
/// `threads` OS threads; results come back in input order. Panics in `f`
/// propagate. Items and results cross thread boundaries by value.
///
/// Work distribution is an atomic-cursor chunked claim: each worker
/// grabs a contiguous index range with one `fetch_add` (~4 claims per
/// worker), instead of the old pop-per-item global `Mutex<Vec<_>>` that
/// serialized every handoff. The per-slot locks below are claimed
/// exactly once each and never contended — they exist only to move
/// items/results across the thread boundary safely.
pub fn scoped_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    assert!(threads > 0);
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.min(n);
    let work: Vec<Mutex<Option<T>>> = items
        .into_iter()
        .map(|t| Mutex::new(Some(t)))
        .collect();
    let results: Vec<Mutex<Option<R>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);
    // ~4 claims per worker balances load skew against cursor traffic.
    let chunk = (n / (threads * 4)).max(1);
    let fref = &f;
    let wref = &work;
    let rref = &results;
    let cref = &cursor;
    thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(move || loop {
                let start = cref.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                for idx in start..(start + chunk).min(n) {
                    let item = wref[idx]
                        .lock()
                        .unwrap()
                        .take()
                        .expect("each index is claimed exactly once");
                    let out = fref(idx, item);
                    *rref[idx].lock().unwrap() = Some(out);
                }
            });
        }
    });
    results
        .into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap()
                .expect("all work completed")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scoped_map_preserves_order() {
        let items: Vec<usize> = (0..97).collect();
        let out = scoped_map(items, 8, |idx, x| {
            assert_eq!(idx, x);
            x * x
        });
        assert_eq!(out, (0..97).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_map_empty() {
        let out: Vec<u32> = scoped_map(Vec::<u32>::new(), 4, |_, x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn scoped_map_single_thread() {
        let out = scoped_map(vec![1, 2, 3], 1, |_, x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn scoped_map_more_threads_than_items() {
        let out = scoped_map(vec![5], 16, |_, x| x * 2);
        assert_eq!(out, vec![10]);
    }

    #[test]
    fn scoped_map_covers_every_index_under_chunked_claim() {
        // Uneven per-item work: the chunked cursor must still cover all
        // indices exactly once and keep results in order.
        let items: Vec<usize> = (0..1023).collect();
        let out = scoped_map(items, 7, |idx, x| {
            if x % 97 == 0 {
                std::thread::yield_now();
            }
            idx * 2 + x
        });
        assert_eq!(out, (0..1023).map(|x| 3 * x).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "panicked")]
    fn scoped_map_propagates_worker_panics() {
        scoped_map((0..32).collect::<Vec<usize>>(), 4, |_, x| {
            if x == 17 {
                panic!("boom");
            }
            x
        });
    }
}
