//! Metrics: counters, histograms, and the CSV/markdown report writers
//! the coordinator and the figure harness share.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::stats::Summary;

/// Retained sample cap per [`Series`]: below this every observation
/// is kept in record order (exact percentiles); beyond it the buffer
/// becomes a uniform reservoir so unbounded runs stay bounded.
pub const SERIES_CAP: usize = 4096;

/// A named scalar time series with bounded memory.
///
/// Count, sum, min and max are tracked exactly for the whole stream;
/// `values` holds every observation until [`SERIES_CAP`], then a
/// uniform reservoir (Algorithm R with a deterministic seeded LCG, so
/// identical streams keep identical reservoirs).
#[derive(Clone, Debug)]
pub struct Series {
    /// Retained observations: exact and in record order while the
    /// stream fits [`SERIES_CAP`], a uniform sample afterwards.
    pub values: Vec<f64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    rng: u64,
}

impl Default for Series {
    fn default() -> Series {
        Series {
            values: Vec::new(),
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            rng: 0x9E37_79B9_7F4A_7C15,
        }
    }
}

impl Series {
    /// Append one observation.
    pub fn record(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
        if self.values.len() < SERIES_CAP {
            self.values.push(x);
        } else {
            self.rng = self
                .rng
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            let j = ((self.rng >> 11) % self.count) as usize;
            if j < SERIES_CAP {
                self.values[j] = x;
            }
        }
    }

    /// Total observations recorded (exact, beyond the reservoir).
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact running sum over the whole stream.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Summary statistics: n, mean, min and max are exact for the
    /// whole stream; percentiles are exact until [`SERIES_CAP`] and
    /// reservoir estimates afterwards.
    pub fn summary(&self) -> Summary {
        let mut s = Summary::of(&self.values);
        if self.count as usize > self.values.len() {
            s.n = self.count as usize;
            s.mean = self.sum / self.count as f64;
            s.min = self.min;
            s.max = self.max;
        }
        s
    }
}

/// A metrics registry: counters + series, keyed by name.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    counters: BTreeMap<String, u64>,
    series: BTreeMap<String, Series>,
}

impl Metrics {
    /// An empty registry.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Add `by` to counter `name` (created at 0).
    pub fn incr(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Current value of counter `name` (0 if never incremented).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Append `x` to series `name` (created empty).
    pub fn observe(&mut self, name: &str, x: f64) {
        self.series
            .entry(name.to_string())
            .or_default()
            .record(x);
    }

    /// The series recorded under `name`, if any.
    pub fn series(&self, name: &str) -> Option<&Series> {
        self.series.get(name)
    }

    /// Human-readable dump (INFO logs, example outputs).
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (name, v) in &self.counters {
            let _ = writeln!(out, "{name:<40} {v}");
        }
        for (name, s) in &self.series {
            let sum = s.summary();
            let _ = writeln!(
                out,
                "{name:<40} n={:<6} mean={:<10.4} p50={:<10.4} p99={:<10.4}",
                sum.n, sum.mean, sum.p50, sum.p99
            );
        }
        out
    }
}

/// A simple CSV table builder used by every figure harness: fixed header,
/// rows of f64 cells, deterministic formatting.
#[derive(Clone, Debug)]
pub struct Table {
    /// Table title (becomes the CSV filename slug).
    pub title: String,
    /// Column names.
    pub header: Vec<String>,
    /// Numeric rows, one Vec per row.
    pub rows: Vec<Vec<f64>>,
}

impl Table {
    /// An empty table with the given title and columns.
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header width).
    pub fn row(&mut self, cells: Vec<f64>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render as CSV (header + rows).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.header.join(","));
        for row in &self.rows {
            let cells: Vec<String> =
                row.iter().map(|x| format!("{x}")).collect();
            let _ = writeln!(out, "{}", cells.join(","));
        }
        out
    }

    /// Markdown rendering for EXPERIMENTS.md and CLI output.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "### {}\n", self.title);
        let _ = writeln!(out, "| {} |", self.header.join(" | "));
        let _ = writeln!(
            out,
            "|{}|",
            self.header.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        );
        for row in &self.rows {
            let cells: Vec<String> =
                row.iter().map(|x| format!("{x:.3}")).collect();
            let _ = writeln!(out, "| {} |", cells.join(" | "));
        }
        out
    }

    /// Write the CSV to `path`, creating parent directories.
    pub fn write_csv(&self, path: impl AsRef<std::path::Path>) -> anyhow::Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_series() {
        let mut m = Metrics::new();
        m.incr("requests", 2);
        m.incr("requests", 3);
        assert_eq!(m.counter("requests"), 5);
        assert_eq!(m.counter("missing"), 0);
        m.observe("latency", 1.0);
        m.observe("latency", 3.0);
        let s = m.series("latency").unwrap().summary();
        assert_eq!(s.n, 2);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert!(m.report().contains("requests"));
    }

    #[test]
    fn series_memory_is_bounded_with_exact_small_n() {
        // Small n: exact record-order behavior, as before.
        let mut s = Series::default();
        for i in 0..5 {
            s.record(i as f64);
        }
        assert_eq!(s.values, vec![0.0, 1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.count(), 5);
        let sm = s.summary();
        assert_eq!(sm.n, 5);
        assert!((sm.mean - 2.0).abs() < 1e-12);

        // Large n: the buffer stays capped while count/sum/min/max
        // remain exact, and identical streams keep identical
        // reservoirs (deterministic replacement).
        let stream = |seed: u64| {
            let mut s = Series::default();
            let mut x = seed;
            for _ in 0..50_000u64 {
                x = x.wrapping_mul(2862933555777941757)
                    .wrapping_add(3037000493);
                s.record((x >> 40) as f64);
            }
            s
        };
        let a = stream(7);
        let b = stream(7);
        assert_eq!(a.values.len(), SERIES_CAP);
        assert_eq!(a.count(), 50_000);
        assert_eq!(a.values, b.values, "reservoir must be deterministic");
        let sa = a.summary();
        assert_eq!(sa.n, 50_000);
        assert!((sa.mean - a.sum() / 50_000.0).abs() < 1e-9);
        assert!(sa.min <= sa.p50 && sa.p50 <= sa.max);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new("Fig X", &["n", "diameter"]);
        t.row(vec![50.0, 12.5]);
        t.row(vec![100.0, 14.0]);
        let csv = t.to_csv();
        assert!(csv.starts_with("n,diameter\n"));
        assert!(csv.contains("50,12.5"));
        let md = t.to_markdown();
        assert!(md.contains("### Fig X"));
        assert!(md.contains("| 50.000 | 12.500 |"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec![1.0]);
    }
}
