//! Property-testing mini-framework (no proptest crate offline —
//! DESIGN.md §3).
//!
//! Deterministic: every case is derived from a seeded [`Rng`], and a
//! failing case reports the case index + seed so it can be replayed
//! exactly. Used by rust/tests/proptests.rs for the coordinator
//! invariants (ring structure, routing, batching, state management).
//!
//! ```no_run
//! // (no_run: doctest binaries miss the xla rpath on this image;
//! // rust/tests/proptests.rs runs this exact pattern for real)
//! use dgro::prop::{forall, Config};
//! forall("ring is permutation", Config::default(), |rng| {
//!     let n = 3 + rng.index(50);
//!     let ring = dgro::topology::random_ring(n, rng);
//!     ring.validate().map_err(|e| e.to_string())
//! });
//! ```

pub mod overlay;

pub use overlay::{connected_over, OverlayCase};

use crate::util::rng::Rng;

/// Knobs for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Cases generated per property.
    pub cases: usize,
    /// Base seed; each case forks a deterministic stream.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 64,
            seed: 0xD62_0_2024, // stable default; override per-property
        }
    }
}

impl Config {
    /// Builder: set the case count.
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    /// Builder: set the base seed.
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// Run `prop` over `config.cases` seeded RNGs; panics with a replayable
/// report on the first failure. `Ok(())` = pass, `Err(msg)` = fail.
pub fn forall(
    name: &str,
    config: Config,
    mut prop: impl FnMut(&mut Rng) -> Result<(), String>,
) {
    for case in 0..config.cases {
        let case_seed = config
            .seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case as u64);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case}/{} \
                 (replay seed: {case_seed:#x}): {msg}",
                config.cases
            );
        }
    }
}

/// Greedily minimize a failing case: repeatedly take the first
/// one-step-smaller candidate (from `shrink`) that still fails, until
/// no candidate fails or `max_evals` property evaluations were spent.
/// Returns the smallest failing case reached (always still failing).
pub fn shrink_case<C: Clone>(
    start: C,
    shrink: impl Fn(&C) -> Vec<C>,
    fails: &mut impl FnMut(&C) -> bool,
    max_evals: usize,
) -> C {
    let mut current = start;
    let mut evals = 0usize;
    'outer: loop {
        for cand in shrink(&current) {
            if evals >= max_evals {
                break 'outer;
            }
            evals += 1;
            if fails(&cand) {
                current = cand;
                continue 'outer;
            }
        }
        break;
    }
    current
}

/// [`forall`] with shrinking: cases come from an explicit generator
/// and a failing case is minimized via [`shrink_case`] before the
/// panic, so the report shows the smallest (`Debug`-printed) input
/// that still violates the property — plus the replay seed for the
/// original draw.
pub fn forall_shrunk<C: Clone + std::fmt::Debug>(
    name: &str,
    config: Config,
    mut generate: impl FnMut(&mut Rng) -> C,
    shrink: impl Fn(&C) -> Vec<C>,
    mut prop: impl FnMut(&C) -> Result<(), String>,
) {
    for case in 0..config.cases {
        let case_seed = config
            .seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case as u64);
        let mut rng = Rng::new(case_seed);
        let drawn = generate(&mut rng);
        if prop(&drawn).is_ok() {
            continue;
        }
        let minimal = shrink_case(
            drawn,
            &shrink,
            &mut |c: &C| prop(c).is_err(),
            10_000,
        );
        let msg = prop(&minimal)
            .err()
            .unwrap_or_else(|| "shrunk case stopped failing".into());
        panic!(
            "property '{name}' failed at case {case}/{} \
             (replay seed: {case_seed:#x}): {msg}\n\
             shrunk case: {minimal:?}",
            config.cases
        );
    }
}

/// Assert-style helper for property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Approximate float equality helper for property bodies.
pub fn ensure_close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("{a} != {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall("trivially true", Config::default().cases(10), |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_panics_with_seed() {
        forall("always false", Config::default().cases(3), |_| {
            Err("nope".to_string())
        });
    }

    #[test]
    fn cases_are_deterministic_across_runs() {
        let mut first = Vec::new();
        forall("collect", Config::default().cases(5), |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second = Vec::new();
        forall("collect", Config::default().cases(5), |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }

    #[test]
    fn passing_shrunk_property_runs_all_cases() {
        let mut count = 0;
        forall_shrunk(
            "small ints pass",
            Config::default().cases(12),
            |rng| rng.index(100),
            |&n| if n > 0 { vec![n - 1, n / 2] } else { vec![] },
            |_| {
                count += 1;
                Ok(())
            },
        );
        assert_eq!(count, 12);
    }

    #[test]
    #[should_panic(expected = "shrunk case: 10")]
    fn failing_shrunk_property_reports_the_minimal_case() {
        // Fails for n >= 10; greedy shrinking must land exactly on 10.
        forall_shrunk(
            "ints below ten",
            Config::default().cases(64),
            |rng| rng.index(1000),
            |&n| if n > 0 { vec![n - 1, n / 2] } else { vec![] },
            |&n| ensure(n < 10, format!("{n} >= 10")),
        );
    }

    #[test]
    fn shrink_case_respects_the_eval_budget() {
        let mut evals = 0usize;
        let out = shrink_case(
            1_000_000usize,
            |&n| if n > 0 { vec![n - 1] } else { vec![] },
            &mut |_| {
                evals += 1;
                true
            },
            5,
        );
        assert_eq!(evals, 5);
        assert_eq!(out, 1_000_000 - 5);
    }

    #[test]
    fn ensure_helpers() {
        assert!(ensure(true, "x").is_ok());
        assert!(ensure(false, "x").is_err());
        assert!(ensure_close(1.0, 1.0 + 1e-12, 1e-9).is_ok());
        assert!(ensure_close(1.0, 2.0, 1e-9).is_err());
    }
}
