//! Property-testing mini-framework (no proptest crate offline —
//! DESIGN.md §3).
//!
//! Deterministic: every case is derived from a seeded [`Rng`], and a
//! failing case reports the case index + seed so it can be replayed
//! exactly. Used by rust/tests/proptests.rs for the coordinator
//! invariants (ring structure, routing, batching, state management).
//!
//! ```no_run
//! // (no_run: doctest binaries miss the xla rpath on this image;
//! // rust/tests/proptests.rs runs this exact pattern for real)
//! use dgro::prop::{forall, Config};
//! forall("ring is permutation", Config::default(), |rng| {
//!     let n = 3 + rng.index(50);
//!     let ring = dgro::topology::random_ring(n, rng);
//!     ring.validate().map_err(|e| e.to_string())
//! });
//! ```

use crate::util::rng::Rng;

/// Knobs for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Cases generated per property.
    pub cases: usize,
    /// Base seed; each case forks a deterministic stream.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 64,
            seed: 0xD62_0_2024, // stable default; override per-property
        }
    }
}

impl Config {
    /// Builder: set the case count.
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    /// Builder: set the base seed.
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// Run `prop` over `config.cases` seeded RNGs; panics with a replayable
/// report on the first failure. `Ok(())` = pass, `Err(msg)` = fail.
pub fn forall(
    name: &str,
    config: Config,
    mut prop: impl FnMut(&mut Rng) -> Result<(), String>,
) {
    for case in 0..config.cases {
        let case_seed = config
            .seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case as u64);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case}/{} \
                 (replay seed: {case_seed:#x}): {msg}",
                config.cases
            );
        }
    }
}

/// Assert-style helper for property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Approximate float equality helper for property bodies.
pub fn ensure_close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("{a} != {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall("trivially true", Config::default().cases(10), |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_panics_with_seed() {
        forall("always false", Config::default().cases(3), |_| {
            Err("nope".to_string())
        });
    }

    #[test]
    fn cases_are_deterministic_across_runs() {
        let mut first = Vec::new();
        forall("collect", Config::default().cases(5), |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second = Vec::new();
        forall("collect", Config::default().cases(5), |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }

    #[test]
    fn ensure_helpers() {
        assert!(ensure(true, "x").is_ok());
        assert!(ensure(false, "x").is_err());
        assert!(ensure_close(1.0, 1.0 + 1e-12, 1e-9).is_ok());
        assert!(ensure_close(1.0, 2.0, 1e-9).is_err());
    }
}
