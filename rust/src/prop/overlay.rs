//! Shrinkable random-overlay cases for the routing property tests.
//!
//! [`OverlayCase`] is a connected alive sub-overlay drawn at random:
//! a universe of `n` nodes, an alive subset (≥ 2 nodes), a ring over
//! the alive set plus random chords, and a seed that derives a
//! *metric* latency matrix (nodes embedded in the plane, weights =
//! Euclidean distance). The metric embedding matters: with the
//! triangle inequality, a direct edge is itself a shortest path, so
//! the stretch-equality-on-neighbors property is structural rather
//! than probabilistic.
//!
//! Cases shrink ([`OverlayCase::shrinks`]) by dropping alive nodes or
//! edges while preserving the generator invariant (alive ≥ 2,
//! connected over the alive set), so [`super::forall_shrunk`] reports
//! a minimal failing overlay instead of a 500-node haystack.

use crate::graph::Graph;
use crate::latency::LatencyMatrix;
use crate::util::rng::Rng;

/// splitmix64 finalizer: one u64 in, one well-mixed u64 out.
fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    x
}

/// Deterministic coordinate of `node` along `axis`, in [0, 100).
fn coord(seed: u64, node: usize, axis: u64) -> f64 {
    let x = mix(
        seed ^ (node as u64).wrapping_mul(0xD1B5_4A32_D192_ED03)
            ^ axis.wrapping_mul(0x2545_F491_4F6C_DD1D),
    );
    (x >> 11) as f64 / (1u64 << 53) as f64 * 100.0
}

/// A randomly drawn, shrinkable overlay: universe, alive subset,
/// undirected alive-to-alive edge list, and the metric seed.
#[derive(Clone, Debug)]
pub struct OverlayCase {
    /// Universe size (node ids are `0..n`).
    pub n: usize,
    /// Alive node ids, sorted, at least 2.
    pub alive: Vec<u32>,
    /// Undirected edges between alive nodes, `(min, max)` normalized.
    pub edges: Vec<(u32, u32)>,
    /// Seed for the planar embedding behind [`OverlayCase::metric`].
    pub seed: u64,
}

impl OverlayCase {
    /// Draw a connected overlay with universe size in `[2, max_n]`.
    pub fn arbitrary(rng: &mut Rng, max_n: usize) -> OverlayCase {
        let max_n = max_n.max(2);
        let n = 2 + rng.index(max_n - 1);
        let alive_count = 2 + rng.index(n - 1);
        let mut perm = rng.permutation(n);
        perm.truncate(alive_count);
        // Base ring over the (shuffled) alive nodes keeps the overlay
        // connected by construction; chords add shortcuts.
        let mut edges: Vec<(u32, u32)> = Vec::new();
        let push = |edges: &mut Vec<(u32, u32)>, a: u32, b: u32| {
            if a == b {
                return;
            }
            let e = (a.min(b), a.max(b));
            if !edges.contains(&e) {
                edges.push(e);
            }
        };
        for i in 0..alive_count {
            let a = perm[i];
            let b = perm[(i + 1) % alive_count];
            push(&mut edges, a, b);
        }
        for _ in 0..rng.index(alive_count + 1) {
            let a = perm[rng.index(alive_count)];
            let b = perm[rng.index(alive_count)];
            push(&mut edges, a, b);
        }
        let mut alive = perm;
        alive.sort_unstable();
        OverlayCase {
            n,
            alive,
            edges,
            seed: rng.next_u64(),
        }
    }

    /// The metric: Euclidean distance between seeded planar points
    /// (zero on the diagonal). Satisfies the triangle inequality.
    pub fn metric(&self) -> LatencyMatrix {
        let seed = self.seed;
        LatencyMatrix::from_fn(self.n, move |u, v| {
            if u == v {
                return 0.0;
            }
            let dx = coord(seed, u, 0) - coord(seed, v, 0);
            let dy = coord(seed, u, 1) - coord(seed, v, 1);
            (dx * dx + dy * dy).sqrt() as f32
        })
    }

    /// Materialize the alive overlay graph (over the full universe —
    /// dead nodes exist but have no edges) and its metric.
    pub fn graph(&self) -> (Graph, LatencyMatrix) {
        let w = self.metric();
        let mut g = Graph::empty(self.n);
        for &(u, v) in &self.edges {
            g.add_edge(u as usize, v as usize, w.get(u as usize, v as usize));
        }
        (g, w)
    }

    /// Whether the alive set is connected under the edge list.
    pub fn is_connected(&self) -> bool {
        connected_over(&self.alive, &self.edges)
    }

    /// One-step smaller candidate cases, each preserving the generator
    /// invariant (alive ≥ 2, connected over alive). Node drops come
    /// first so shrinking reduces the overlay before thinning edges.
    pub fn shrinks(&self) -> Vec<OverlayCase> {
        let mut out = Vec::new();
        if self.alive.len() > 2 {
            for (i, &dead) in self.alive.iter().enumerate() {
                let mut c = self.clone();
                c.alive.remove(i);
                c.edges.retain(|&(u, v)| u != dead && v != dead);
                if c.is_connected() {
                    out.push(c);
                }
            }
        }
        for i in 0..self.edges.len() {
            let mut c = self.clone();
            c.edges.remove(i);
            if c.is_connected() {
                out.push(c);
            }
        }
        // Tighten the universe once nothing above it is alive.
        let top = self.alive.last().map_or(0, |&a| a as usize + 1);
        if top < self.n {
            let mut c = self.clone();
            c.n = top;
            out.push(c);
        }
        out
    }
}

/// BFS connectivity of `alive` under the undirected `edges` list
/// (edges touching non-alive nodes are ignored).
pub fn connected_over(alive: &[u32], edges: &[(u32, u32)]) -> bool {
    if alive.len() <= 1 {
        return !alive.is_empty();
    }
    let idx = |x: u32| alive.binary_search(&x).ok();
    let mut adj: Vec<Vec<usize>> = vec![Vec::new(); alive.len()];
    for &(u, v) in edges {
        if let (Some(a), Some(b)) = (idx(u), idx(v)) {
            adj[a].push(b);
            adj[b].push(a);
        }
    }
    let mut seen = vec![false; alive.len()];
    let mut queue = vec![0usize];
    seen[0] = true;
    let mut reached = 1;
    while let Some(a) = queue.pop() {
        for &b in &adj[a] {
            if !seen[b] {
                seen[b] = true;
                reached += 1;
                queue.push(b);
            }
        }
    }
    reached == alive.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::shrink_case;

    #[test]
    fn arbitrary_cases_are_connected_and_well_formed() {
        let mut rng = Rng::new(0xCA5E);
        for _ in 0..64 {
            let c = OverlayCase::arbitrary(&mut rng, 64);
            assert!(c.alive.len() >= 2);
            assert!(c.alive.windows(2).all(|w| w[0] < w[1]));
            assert!(c.is_connected(), "generator must emit connected overlays");
            for &(u, v) in &c.edges {
                assert!(u < v, "edges must be normalized");
                assert!(c.alive.binary_search(&u).is_ok());
                assert!(c.alive.binary_search(&v).is_ok());
            }
            let (g, w) = c.graph();
            assert_eq!(g.n(), c.n);
            assert_eq!(g.m(), c.edges.len());
            assert_eq!(w.n(), c.n);
        }
    }

    #[test]
    fn metric_satisfies_triangle_inequality_on_samples() {
        let c = OverlayCase {
            n: 12,
            alive: (0..12).collect(),
            edges: vec![],
            seed: 99,
        };
        let w = c.metric();
        for u in 0..12 {
            for v in 0..12 {
                assert_eq!(w.get(u, v), w.get(v, u));
                for k in 0..12 {
                    assert!(
                        w.get(u, v) <= w.get(u, k) + w.get(k, v) + 1e-3,
                        "triangle violated at ({u},{k},{v})"
                    );
                }
            }
        }
    }

    #[test]
    fn shrinks_preserve_the_invariant_and_reduce() {
        let mut rng = Rng::new(7);
        let c = OverlayCase::arbitrary(&mut rng, 48);
        for s in c.shrinks() {
            assert!(s.alive.len() >= 2);
            assert!(s.is_connected());
            assert!(
                s.alive.len() < c.alive.len()
                    || s.edges.len() < c.edges.len()
                    || s.n < c.n,
                "every shrink candidate must be strictly smaller"
            );
        }
    }

    #[test]
    fn shrinking_finds_the_minimal_failing_overlay() {
        // Property: "fewer than 4 alive nodes". The minimal failing
        // case has exactly 4 alive nodes and a spanning tree (3 edges).
        let mut rng = Rng::new(0xBEEF);
        let start = loop {
            let c = OverlayCase::arbitrary(&mut rng, 64);
            if c.alive.len() >= 6 {
                break c;
            }
        };
        let mut fails = |c: &OverlayCase| c.alive.len() >= 4;
        let minimal =
            shrink_case(start, |c| c.shrinks(), &mut fails, 100_000);
        assert_eq!(minimal.alive.len(), 4);
        assert_eq!(minimal.edges.len(), 3);
        assert_eq!(minimal.n, *minimal.alive.last().unwrap() as usize + 1);
    }
}
