//! `cargo bench --bench hotpath` — micro/meso benchmarks of the L3 hot
//! paths feeding EXPERIMENTS.md §Perf:
//!
//!   * APSP/diameter, serial vs [`EvalPool`]-parallel, at n ∈
//!     {128, 512, 1024} (1024 in full mode), plus population batches
//!   * ring construction (greedy + native Q-net + PJRT Q-net per step)
//!   * gossip measurement round
//!   * broadcast simulation
//!   * GA evaluation throughput, serial vs batched-parallel
//!   * scenario engine periods/s, from-scratch rebuild vs incremental
//!   * coordinator periods/s, centralized vs sharded (K=8)
//!   * net coordinator frames/s over the sim and udp loopback
//!     transports, plus probe-RTT overhead and sim-vs-udp diameter drift
//!   * scale tier: certified diameter estimation on 10^4/10^5-node
//!     circulant and random-geometric graphs (runs in quick mode too)
//!   * traffic tier: greedy routing + FIFO queueing throughput and p99
//!     end-to-end latency over a static K-ring (docs/TRAFFIC.md)
//!   * observability tier: span recording on/off and causal-trace
//!     stamping on/off throughput ratios (docs/OBSERVABILITY.md)
//!
//! Besides the stdout report, the run writes **BENCH_hotpath.json** to
//! the working directory (repo root under `cargo bench`): the
//! machine-readable perf trajectory CI uploads per commit. Modes:
//! `--quick` / DGRO_BENCH_QUICK=1 trims sizes and iterations (the CI
//! smoke), `--threads=N` / DGRO_THREADS pins the pool width (default:
//! all cores). Statistical harness from util::timer/stats (no criterion
//! offline).

#![allow(clippy::field_reassign_with_default)] // config-mutation idiom

use dgro::dgro::construct::{build_ring, GreedyScorer};
use dgro::graph::eval::EvalPool;
use dgro::graph::{apsp, diameter, Graph};
use dgro::gossip::measure::{measure, MeasureConfig};
use dgro::latency::Model;
use dgro::qnet::native::NativeQnet;
use dgro::qnet::params::QnetParams;
use dgro::qnet::state::State;
use dgro::qnet::QScorer;
use dgro::runtime::{ArtifactStore, PjrtQnet};
use dgro::scenario::{
    ChurnSpec, ScenarioEngine, ScenarioReport, ScenarioSpec, Topology,
};
use dgro::sim::broadcast::broadcast_times;
use dgro::topology::circulant::Circulant;
use dgro::topology::genetic::{self, GaConfig};
use dgro::topology::{
    geometric_radius, paper_k, random_geometric, random_ring,
};
use dgro::util::json::Json;
use dgro::util::rng::Rng;
use dgro::util::stats::Summary;
use dgro::util::timer::time_iters;

fn report(name: &str, samples: &[f64], unit_per_iter: Option<(&str, f64)>) {
    let s = Summary::of(samples);
    print!(
        "{name:<44} mean {:>10.4} ms  p50 {:>10.4}  p99 {:>10.4}",
        s.mean * 1e3,
        s.p50 * 1e3,
        s.p99 * 1e3
    );
    if let Some((unit, count)) = unit_per_iter {
        print!("  ({:.1} {unit}/s)", count / s.mean);
    }
    println!();
}

fn mean_s(samples: &[f64]) -> f64 {
    Summary::of(samples).mean.max(1e-12)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let quick = std::env::var("DGRO_BENCH_QUICK").ok().as_deref()
        == Some("1")
        || args.iter().any(|a| a == "--quick" || a == "quick");
    let threads = args
        .iter()
        .find_map(|a| {
            a.strip_prefix("--threads=").and_then(|v| v.parse().ok())
        })
        .or_else(|| {
            std::env::var("DGRO_THREADS").ok().and_then(|v| v.parse().ok())
        })
        .unwrap_or_else(EvalPool::default_threads);
    println!("hotpath bench: quick={quick} threads={threads}");

    let mut rng = Rng::new(0xBEEF);
    let pool = EvalPool::new(threads);
    let serial_pool = EvalPool::serial();

    // --- APSP / diameter, serial vs parallel. --------------------------
    let sizes: &[usize] = if quick { &[128, 512] } else { &[128, 512, 1024] };
    let mut apsp_rows = Vec::new();
    let mut diam_rows = Vec::new();
    for &n in sizes {
        let w = Model::Uniform.sample(n, &mut rng);
        let k = paper_k(n);
        let g = dgro::topology::kring::random_krings(n, k, &mut rng)
            .to_graph(&w);
        let iters = if n >= 1024 {
            2
        } else if n >= 512 {
            3
        } else {
            10
        };

        let s_apsp = time_iters(1, iters, || apsp::apsp(&g));
        let p_apsp = time_iters(1, iters, || pool.apsp_par(&g));
        report(&format!("apsp serial n={n}"), &s_apsp, None);
        report(&format!("apsp parallel n={n} T={threads}"), &p_apsp, None);
        // Equivalence: the striped rows must match the serial matrix.
        let a = apsp::apsp(&g);
        let b = pool.apsp_par(&g);
        let mut apsp_diff = 0.0f64;
        for (x, y) in a.d.iter().zip(&b.d) {
            if x.to_bits() != y.to_bits() {
                apsp_diff = apsp_diff.max((x - y).abs() as f64);
            }
        }
        let (sm, pm) = (mean_s(&s_apsp), mean_s(&p_apsp));
        apsp_rows.push(Json::obj(vec![
            ("n", Json::num(n as f64)),
            ("serial_ms", Json::num(sm * 1e3)),
            ("par_ms", Json::num(pm * 1e3)),
            ("speedup", Json::num(sm / pm)),
            ("max_abs_diff", Json::num(apsp_diff)),
        ]));

        let s_d = time_iters(1, iters, || diameter::diameter(&g));
        let p_d = time_iters(1, iters, || pool.diameter_par(&g));
        report(&format!("diameter serial n={n} k={k}"), &s_d, None);
        report(
            &format!("diameter parallel n={n} T={threads}"),
            &p_d,
            None,
        );
        let d_serial = diameter::diameter(&g);
        let d_par = pool.diameter_par(&g);

        // Population batch (the GA generation / compare cross-product
        // shape): one diameter per candidate graph.
        let bsz = if n >= 1024 { 8 } else { 16 };
        let cands: Vec<Graph> = (0..bsz)
            .map(|_| {
                dgro::topology::kring::random_krings(n, k, &mut rng)
                    .to_graph(&w)
            })
            .collect();
        let s_b =
            time_iters(0, iters, || serial_pool.diameter_batch(&cands));
        let p_b = time_iters(0, iters, || pool.diameter_batch(&cands));
        report(
            &format!("diameter_batch {bsz}x serial n={n}"),
            &s_b,
            Some(("graphs", bsz as f64)),
        );
        report(
            &format!("diameter_batch {bsz}x T={threads} n={n}"),
            &p_b,
            Some(("graphs", bsz as f64)),
        );
        let ds = serial_pool.diameter_batch(&cands);
        let dp = pool.diameter_batch(&cands);
        let mut batch_diff = 0.0f64;
        for (x, y) in ds.iter().zip(&dp) {
            batch_diff = batch_diff.max((x - y).abs() as f64);
        }
        let (sdm, pdm) = (mean_s(&s_d), mean_s(&p_d));
        let (sbm, pbm) = (mean_s(&s_b), mean_s(&p_b));
        diam_rows.push(Json::obj(vec![
            ("n", Json::num(n as f64)),
            ("serial_ms", Json::num(sdm * 1e3)),
            ("par_ms", Json::num(pdm * 1e3)),
            ("speedup", Json::num(sdm / pdm)),
            ("diff", Json::num((d_serial - d_par).abs() as f64)),
            ("batch", Json::num(bsz as f64)),
            ("batch_serial_ms", Json::num(sbm * 1e3)),
            ("batch_par_ms", Json::num(pbm * 1e3)),
            ("batch_speedup", Json::num(sbm / pbm)),
            ("batch_max_abs_diff", Json::num(batch_diff)),
        ]));
    }

    // --- Ring construction per scorer. ---------------------------------
    let n = 120;
    let w = Model::Fabric.sample(n, &mut rng);
    let samples = time_iters(2, 10, || {
        build_ring(&mut GreedyScorer, &w, 0).unwrap()
    });
    report("ring construction greedy n=120", &samples,
           Some(("steps", n as f64)));

    let mut native = NativeQnet::new(
        ArtifactStore::discover(ArtifactStore::default_dir())
            .and_then(|s| s.load_params())
            .unwrap_or_else(|_| QnetParams::synthetic(16, 32, 7)),
    );
    let samples = time_iters(1, 5, || {
        build_ring(&mut native, &w, 0).unwrap()
    });
    report("ring construction native-qnet n=120", &samples,
           Some(("steps", n as f64)));

    // Single-step scoring latency (the Algorithm-1 inner loop).
    let st = State::new(&w, 0);
    let samples = time_iters(2, 20, || native.score(&st).unwrap());
    report("qnet score (native) n=120", &samples, None);

    match ArtifactStore::discover(ArtifactStore::default_dir())
        .and_then(PjrtQnet::new)
    {
        Ok(mut pjrt) => {
            // Warm the executable cache, then measure steady state.
            let _ = pjrt.score(&st).unwrap();
            let samples = time_iters(2, 20, || pjrt.score(&st).unwrap());
            report("qnet score (pjrt AOT HLO) n=120", &samples, None);
            let samples = time_iters(0, 3, || {
                build_ring(&mut pjrt, &w, 0).unwrap()
            });
            report("ring construction pjrt-qnet n=120", &samples,
                   Some(("steps", n as f64)));
        }
        Err(e) => println!("pjrt benches skipped: {e}"),
    }

    // --- Gossip + broadcast. -------------------------------------------
    let g = dgro::topology::kring::random_krings(n, paper_k(n), &mut rng)
        .to_graph(&w);
    let mut grng = Rng::new(1);
    let samples = time_iters(2, 20, || {
        measure(&w, &g, MeasureConfig::default(), &mut grng)
    });
    report("gossip measurement (Alg 3) n=120", &samples, None);

    let proc = vec![1.0; n];
    let samples = time_iters(2, 50, || broadcast_times(&g, 0, &proc));
    report("broadcast simulation n=120", &samples, None);

    // --- GA throughput (topology evaluations / s), serial vs pool. -----
    let budget = if quick { 300 } else { 2_000 };
    let ga_iters = if quick { 2 } else { 3 };
    let mut garng = Rng::new(2);
    let s_ga = time_iters(0, ga_iters, || {
        genetic::search(
            &w,
            2,
            GaConfig {
                budget,
                ..Default::default()
            },
            &mut garng,
        )
    });
    report(
        &format!("GA search {budget} evals serial n=120"),
        &s_ga,
        Some(("evals", budget as f64)),
    );
    let mut garng = Rng::new(2);
    let p_ga = time_iters(0, ga_iters, || {
        genetic::search(
            &w,
            2,
            GaConfig {
                budget,
                threads,
                ..Default::default()
            },
            &mut garng,
        )
    });
    report(
        &format!("GA search {budget} evals T={threads} n=120"),
        &p_ga,
        Some(("evals", budget as f64)),
    );
    let (gsm, gpm) = (mean_s(&s_ga), mean_s(&p_ga));
    let ga_json = Json::obj(vec![
        ("n", Json::num(120.0)),
        ("budget", Json::num(budget as f64)),
        ("serial_evals_per_s", Json::num(budget as f64 / gsm)),
        ("par_evals_per_s", Json::num(budget as f64 / gpm)),
        ("speedup", Json::num(gsm / gpm)),
    ]);

    // --- Scenario engine periods/s: rebuild vs incremental. ------------
    let scen_nodes = 512usize;
    let spec = ScenarioSpec {
        name: "bench-churn".into(),
        about: "hotpath bench workload".into(),
        nodes: scen_nodes,
        initial_alive: scen_nodes,
        model: "uniform".into(),
        horizon: 2000.0,
        churn: vec![ChurnSpec::Poisson { rate: 0.001 }],
        latency: vec![],
    };
    let mut rebuild = ScenarioEngine::new(spec.clone(), 7)?;
    rebuild.opts.incremental = false;
    let mut incremental = ScenarioEngine::new(spec, 7)?;
    incremental.opts.threads = threads;
    let scen_iters = if quick { 2 } else { 3 };
    // Keep the last timed run of each engine for the equivalence diff
    // instead of paying for an extra untimed run.
    let mut rep_a: Option<ScenarioReport> = None;
    let mut rep_b: Option<ScenarioReport> = None;
    let s_sc = time_iters(0, scen_iters, || {
        rep_a = Some(
            rebuild.run(Topology::Chord).expect("rebuild scenario run"),
        );
    });
    let p_sc = time_iters(0, scen_iters, || {
        rep_b = Some(
            incremental
                .run(Topology::Chord)
                .expect("incremental scenario run"),
        );
    });
    let a = rep_a.expect("timed at least one rebuild run");
    let b = rep_b.expect("timed at least one incremental run");
    let periods = a.rows.len() as f64;
    let mut scen_diff = 0.0f64;
    for (x, y) in a.rows.iter().zip(&b.rows) {
        scen_diff = scen_diff.max((x.diameter - y.diameter).abs());
    }
    report(
        &format!("scenario rebuild n={scen_nodes}"),
        &s_sc,
        Some(("periods", periods)),
    );
    report(
        &format!("scenario incremental n={scen_nodes} T={threads}"),
        &p_sc,
        Some(("periods", periods)),
    );
    let (ssm, spm) = (mean_s(&s_sc), mean_s(&p_sc));
    let scenario_json = Json::obj(vec![
        ("n", Json::num(scen_nodes as f64)),
        ("periods", Json::num(periods)),
        ("rebuild_ms", Json::num(ssm * 1e3)),
        ("incremental_ms", Json::num(spm * 1e3)),
        ("rebuild_periods_per_s", Json::num(periods / ssm)),
        ("incremental_periods_per_s", Json::num(periods / spm)),
        ("speedup", Json::num(ssm / spm)),
        ("max_abs_diameter_diff", Json::num(scen_diff)),
    ]);

    // --- Sharded vs centralized coordinator periods/s. ------------------
    let sh_nodes = 512usize;
    let shard_k = 8usize;
    let sh_spec = ScenarioSpec {
        name: "bench-sharded".into(),
        about: "sharded-coordinator hotpath workload".into(),
        nodes: sh_nodes,
        initial_alive: sh_nodes,
        model: "fabric".into(),
        horizon: if quick { 1000.0 } else { 2000.0 },
        churn: vec![ChurnSpec::Poisson { rate: 0.0005 }],
        latency: vec![],
    };
    let mut central = ScenarioEngine::new(sh_spec.clone(), 7)?;
    central.opts.threads = threads;
    let mut shard_eng = ScenarioEngine::new(sh_spec, 7)?;
    shard_eng.opts.threads = threads;
    shard_eng.opts.shards = shard_k;
    let sh_iters = if quick { 1 } else { 2 };
    let mut rep_c: Option<ScenarioReport> = None;
    let mut rep_s: Option<ScenarioReport> = None;
    let c_t = time_iters(0, sh_iters, || {
        rep_c = Some(
            central.run(Topology::Dgro).expect("centralized run"),
        );
    });
    let s_t = time_iters(0, sh_iters, || {
        rep_s = Some(
            shard_eng
                .run(Topology::DgroSharded)
                .expect("sharded run"),
        );
    });
    let rc = rep_c.expect("timed at least one centralized run");
    let rs = rep_s.expect("timed at least one sharded run");
    assert_eq!(
        rc.rows.len(),
        rs.rows.len(),
        "centralized and sharded runs must cover the same periods"
    );
    let sh_periods = rc.rows.len() as f64;
    report(
        &format!("coordinator centralized n={sh_nodes}"),
        &c_t,
        Some(("periods", sh_periods)),
    );
    report(
        &format!("coordinator sharded K={shard_k} n={sh_nodes} T={threads}"),
        &s_t,
        Some(("periods", sh_periods)),
    );
    let (ctm, stm) = (mean_s(&c_t), mean_s(&s_t));
    let sharded_json = Json::obj(vec![
        ("n", Json::num(sh_nodes as f64)),
        ("shards", Json::num(shard_k as f64)),
        ("periods", Json::num(sh_periods)),
        ("centralized_ms", Json::num(ctm * 1e3)),
        ("sharded_ms", Json::num(stm * 1e3)),
        ("centralized_periods_per_s", Json::num(sh_periods / ctm)),
        ("sharded_periods_per_s", Json::num(sh_periods / stm)),
        ("speedup", Json::num(ctm / stm)),
        ("mean_diameter_centralized", Json::num(rc.mean_diameter())),
        ("mean_diameter_sharded", Json::num(rs.mean_diameter())),
    ]);

    // --- Real-socket transport: frames/s + probe RTT overhead. ----------
    let net_nodes = if quick { 24 } else { 48 };
    let net_horizon = if quick { 500.0 } else { 1000.0 };
    let mut ncfg = dgro::config::Config::default();
    ncfg.nodes = net_nodes;
    ncfg.model = "fabric".to_string();
    ncfg.scorer = "greedy".to_string();
    ncfg.adapt_period_ms = 250.0;
    ncfg.seed = 7;
    let mut nrng = Rng::new(7);
    let nw = Model::Fabric.sample(net_nodes, &mut nrng);
    let mut trng = Rng::new(0xC0FFEE);
    let net_trace = dgro::membership::events::EventTrace::churn(
        net_nodes,
        net_horizon,
        0.001,
        &mut trng,
    );
    let t0 = std::time::Instant::now();
    let mut sim_co = dgro::net::NetCoordinator::new(
        ncfg.clone(),
        nw.clone(),
        dgro::net::SimTransport::new(nw.clone()),
    )?;
    let rep_sim = sim_co.run(&net_trace, net_horizon)?;
    let sim_wall = t0.elapsed().as_secs_f64().max(1e-9);
    let sim_frames = sim_co.frames_sent();
    report(
        &format!("net coordinator sim n={net_nodes}"),
        &[sim_wall],
        Some(("frames", sim_frames as f64)),
    );
    let t0 = std::time::Instant::now();
    let mut udp_co = dgro::net::NetCoordinator::new(
        ncfg.clone(),
        nw.clone(),
        dgro::net::UdpTransport::bind(
            nw.clone(),
            dgro::net::UdpTransport::DEFAULT_TIME_SCALE,
        )?,
    )?;
    let rep_udp = udp_co.run(&net_trace, net_horizon)?;
    let udp_wall = t0.elapsed().as_secs_f64().max(1e-9);
    let udp_frames = udp_co.frames_sent();
    report(
        &format!("net coordinator udp n={net_nodes}"),
        &[udp_wall],
        Some(("frames", udp_frames as f64)),
    );
    let t0 = std::time::Instant::now();
    let mut tcp_co = dgro::net::NetCoordinator::new(
        ncfg.clone(),
        nw.clone(),
        dgro::net::TcpTransport::bind(
            nw.clone(),
            dgro::net::UdpTransport::DEFAULT_TIME_SCALE,
        )?,
    )?;
    let rep_tcp = tcp_co.run(&net_trace, net_horizon)?;
    let tcp_wall = t0.elapsed().as_secs_f64().max(1e-9);
    let tcp_frames = tcp_co.frames_sent();
    report(
        &format!("net coordinator tcp n={net_nodes}"),
        &[tcp_wall],
        Some(("frames", tcp_frames as f64)),
    );
    // Coordinator-free runner over the same world/trace: adaptation
    // periods per second of the full per-peer protocol (membership
    // flood, push-sum measurement, two-phase swaps, ring anti-entropy).
    // bench_gate floors `decentralized_periods_per_s`.
    let t0 = std::time::Instant::now();
    let mut dec_co = dgro::coordinator::DecentralizedRunner::new(
        ncfg.clone(),
        nw.clone(),
        dgro::net::SimTransport::new(nw.clone()),
    )?;
    let rep_dec = {
        use dgro::coordinator::{AdaptiveRunner, RunOptions};
        dec_co.run_with(&net_trace, net_horizon, RunOptions::new())?
    };
    let dec_wall = t0.elapsed().as_secs_f64().max(1e-9);
    let dec_frames = dec_co.frames_sent();
    report(
        &format!("decentralized runner sim n={net_nodes}"),
        &[dec_wall],
        Some(("frames", dec_frames as f64)),
    );
    // Probe overhead: how far measured one-way RTT/2 strays from the
    // shaped matrix latency (0 on sim by construction).
    let rtt_overhead =
        udp_co.obs.reg.histogram("net.rtt_abs_error_ms").mean();
    let mut parity_diff = 0.0f64;
    for (a, b) in rep_sim.timeline.iter().zip(&rep_udp.timeline) {
        parity_diff = parity_diff.max((a.2 - b.2).abs() as f64);
    }
    let mut parity_tcp = 0.0f64;
    for (a, b) in rep_sim.timeline.iter().zip(&rep_tcp.timeline) {
        parity_tcp = parity_tcp.max((a.2 - b.2).abs() as f64);
    }
    println!(
        "net probe rtt overhead {rtt_overhead:.3} ms; \
         sim-vs-udp max diameter diff {parity_diff:.3}; \
         sim-vs-tcp {parity_tcp:.3}"
    );
    let net_json = Json::obj(vec![
        ("n", Json::num(net_nodes as f64)),
        ("periods", Json::num(rep_sim.timeline.len() as f64)),
        ("sim_frames", Json::num(sim_frames as f64)),
        ("sim_frames_per_s", Json::num(sim_frames as f64 / sim_wall)),
        ("udp_frames", Json::num(udp_frames as f64)),
        ("udp_frames_per_s", Json::num(udp_frames as f64 / udp_wall)),
        (
            "udp_frames_lost",
            Json::num(udp_co.metrics.counter("net.frames_lost") as f64),
        ),
        ("tcp_frames", Json::num(tcp_frames as f64)),
        ("tcp_frames_per_s", Json::num(tcp_frames as f64 / tcp_wall)),
        (
            "decentralized_periods_per_s",
            Json::num(rep_dec.timeline.len() as f64 / dec_wall),
        ),
        ("decentralized_frames", Json::num(dec_frames as f64)),
        (
            "tcp_stale_frames",
            Json::num(tcp_co.metrics.counter("net.stale_frames") as f64),
        ),
        ("probe_rtt_overhead_ms", Json::num(rtt_overhead)),
        ("max_diameter_diff", Json::num(parity_diff)),
        ("max_diameter_diff_tcp", Json::num(parity_tcp)),
    ]);

    // --- Observability overhead: span recording on vs off. --------------
    // Same adaptive workload twice; the only difference is whether the
    // flight recorder captures period/measure/decide/swap spans.
    // bench_gate floors the throughput ratio so instrumentation creep
    // on the hot loop fails CI.
    let obs_nodes = 256usize;
    let obs_spec = ScenarioSpec {
        name: "bench-obs".into(),
        about: "observability-overhead workload".into(),
        nodes: obs_nodes,
        initial_alive: obs_nodes,
        model: "uniform".into(),
        horizon: if quick { 1000.0 } else { 2000.0 },
        churn: vec![ChurnSpec::Poisson { rate: 0.001 }],
        latency: vec![],
    };
    let mut obs_off = ScenarioEngine::new(obs_spec.clone(), 7)?;
    obs_off.opts.threads = threads;
    let mut obs_on = ScenarioEngine::new(obs_spec, 7)?;
    obs_on.opts.threads = threads;
    obs_on.opts.obs_record = true;
    let obs_iters = if quick { 2 } else { 3 };
    let off_t = time_iters(0, obs_iters, || {
        obs_off.run(Topology::Dgro).expect("obs-off run");
    });
    let on_t = time_iters(0, obs_iters, || {
        obs_on.run(Topology::Dgro).expect("obs-on run");
    });
    let (offm, onm) = (mean_s(&off_t), mean_s(&on_t));
    let obs_ratio = offm / onm;
    println!(
        "obs recording off {:.2} ms, on {:.2} ms \
         (enabled/disabled throughput {obs_ratio:.3})",
        offm * 1e3,
        onm * 1e3
    );
    let obs_json = Json::obj(vec![
        ("n", Json::num(obs_nodes as f64)),
        ("disabled_ms", Json::num(offm * 1e3)),
        ("enabled_ms", Json::num(onm * 1e3)),
        ("enabled_over_disabled_ratio", Json::num(obs_ratio)),
    ]);

    // --- Causal-tracing overhead: wire trace context on vs off. ----------
    // Transport-backed sim replay with the recorder enabled in BOTH
    // runs, so the only delta is what --trace-sample 1 adds: the
    // 16-byte wire context, span-id derivation, and per-delivery span
    // records. bench_gate floors the throughput ratio so trace
    // stamping on the frame hot path cannot silently regress.
    let tr_nodes = 64usize;
    let tr_spec = ScenarioSpec {
        name: "bench-trace".into(),
        about: "causal-tracing-overhead workload".into(),
        nodes: tr_nodes,
        initial_alive: tr_nodes,
        model: "uniform".into(),
        horizon: 1000.0,
        churn: vec![ChurnSpec::Poisson { rate: 0.001 }],
        latency: vec![],
    };
    let mut tr_off = ScenarioEngine::new(tr_spec.clone(), 7)?;
    tr_off.opts.transport = Some(dgro::net::TransportKind::Sim);
    tr_off.opts.obs_record = true;
    let mut tr_on = ScenarioEngine::new(tr_spec, 7)?;
    tr_on.opts.transport = Some(dgro::net::TransportKind::Sim);
    tr_on.opts.obs_record = true;
    tr_on.opts.trace_sample = 1;
    let tr_iters = if quick { 2 } else { 3 };
    let troff_t = time_iters(0, tr_iters, || {
        tr_off.run(Topology::Dgro).expect("trace-off run");
    });
    let tron_t = time_iters(0, tr_iters, || {
        tr_on.run(Topology::Dgro).expect("trace-on run");
    });
    let (troffm, tronm) = (mean_s(&troff_t), mean_s(&tron_t));
    let trace_ratio = troffm / tronm;
    println!(
        "trace stamping off {:.2} ms, on {:.2} ms \
         (enabled/disabled throughput {trace_ratio:.3})",
        troffm * 1e3,
        tronm * 1e3
    );
    let trace_json = Json::obj(vec![
        ("n", Json::num(tr_nodes as f64)),
        ("disabled_ms", Json::num(troffm * 1e3)),
        ("enabled_ms", Json::num(tronm * 1e3)),
        ("enabled_over_disabled_ratio", Json::num(trace_ratio)),
    ]);

    // --- Scale tier: certified diameter estimates at 10^4–10^5 nodes. ---
    // Dense LatencyMatrix paths stop near 10^3 (n² f32 cells); this
    // tier builds sparse graphs directly — the circulant family, whose
    // hop diameter is known in closed form, and the irregular
    // random-geometric family — and times `diameter_est` at the
    // default sketch budget. bench_gate floors the 10^5 estimation
    // throughputs; the tier runs in quick mode too so CI tracks it.
    let scale_budget = 16usize;
    let mut scale_rows = Vec::new();
    let fin = |x: f32| if x.is_finite() { f64::from(x) } else { -1.0 };
    for &sn in &[10_000usize, 100_000] {
        let t0 = std::time::Instant::now();
        let circ = Circulant::power_two(sn);
        let cg = circ.unit_graph();
        let c_build = t0.elapsed().as_secs_f64();
        let exact_hops = circ.hop_diameter() as f64;
        let t0 = std::time::Instant::now();
        let ce = pool.diameter_est(&cg, &[], scale_budget);
        let c_est = t0.elapsed().as_secs_f64().max(1e-9);
        assert!(
            f64::from(ce.lower) <= exact_hops + 1e-6
                && exact_hops <= f64::from(ce.upper) + 1e-6,
            "circulant n={sn}: exact {exact_hops} outside [{}, {}]",
            ce.lower,
            ce.upper
        );
        report(
            &format!("scale circulant n={sn} T={threads}"),
            &[c_est],
            Some(("nodes", sn as f64)),
        );
        scale_rows.push(Json::obj(vec![
            ("family", Json::str("circulant")),
            ("n", Json::num(sn as f64)),
            ("m", Json::num(cg.m() as f64)),
            ("build_ms", Json::num(c_build * 1e3)),
            ("est_ms", Json::num(c_est * 1e3)),
            ("est_nodes_per_s", Json::num(sn as f64 / c_est)),
            ("lower", Json::num(fin(ce.lower))),
            ("upper", Json::num(fin(ce.upper))),
            ("exact_hops", Json::num(exact_hops)),
            ("gap_pct", Json::num(ce.gap_pct())),
            ("sweeps", Json::num(ce.sweeps as f64)),
        ]));

        let mut srng = Rng::new(0x5CA1E + sn as u64);
        let t0 = std::time::Instant::now();
        let rg = random_geometric(sn, geometric_radius(sn), &mut srng);
        let r_build = t0.elapsed().as_secs_f64();
        let t0 = std::time::Instant::now();
        let re = pool.diameter_est(&rg, &[], scale_budget);
        let r_est = t0.elapsed().as_secs_f64().max(1e-9);
        report(
            &format!("scale geometric n={sn} T={threads}"),
            &[r_est],
            Some(("nodes", sn as f64)),
        );
        scale_rows.push(Json::obj(vec![
            ("family", Json::str("geometric")),
            ("n", Json::num(sn as f64)),
            ("m", Json::num(rg.m() as f64)),
            ("build_ms", Json::num(r_build * 1e3)),
            ("est_ms", Json::num(r_est * 1e3)),
            ("est_nodes_per_s", Json::num(sn as f64 / r_est)),
            ("lower", Json::num(fin(re.lower))),
            ("upper", Json::num(fin(re.upper))),
            ("gap_pct", Json::num(re.gap_pct())),
            ("sweeps", Json::num(re.sweeps as f64)),
        ]));
    }

    // --- Traffic plane: routed requests/s over a static K-ring. ---------
    // Isolates the traffic subsystem (greedy routing + FIFO queueing +
    // retry bookkeeping) from the scenario engine's diameter sweeps: a
    // fixed K-ring world, the default 2·10^5 req/s open-loop workload.
    // bench_gate floors req/s and ceilings the p99 latency.
    let t_nodes = if quick { 128 } else { 256 };
    let mut t_rng = Rng::new(0x7AFF);
    let tw = Model::Fabric.sample(t_nodes, &mut t_rng);
    let tg = dgro::topology::kring::random_krings(
        t_nodes,
        paper_k(t_nodes),
        &mut t_rng,
    )
    .to_graph(&tw);
    let t_alive: Vec<u32> = (0..t_nodes as u32).collect();
    let mut t_cfg = dgro::traffic::TrafficConfig::default();
    t_cfg.rate = 200_000.0;
    let t_periods = if quick { 4 } else { 8 };
    let t0 = std::time::Instant::now();
    let mut t_sim =
        dgro::traffic::TrafficSim::new(t_nodes, 7, t_cfg, threads);
    for p in 1..=t_periods {
        t_sim.on_period(p as f64 * 250.0, &tg, &tw, &t_alive);
    }
    let (t_rep, _) = t_sim.finish("bench-traffic", "random", 7);
    let t_wall = t0.elapsed().as_secs_f64().max(1e-9);
    report(
        &format!("traffic route+queue n={t_nodes} T={threads}"),
        &[t_wall],
        Some(("reqs", t_rep.offered as f64)),
    );
    println!(
        "traffic p50 {:.3} ms p99 {:.3} ms success {:.4} stretch {:.3}",
        t_rep.p50_ms,
        t_rep.p99_ms,
        t_rep.success_rate(),
        t_rep.mean_stretch
    );
    let traffic_json = Json::obj(vec![
        ("n", Json::num(t_nodes as f64)),
        ("periods", Json::num(t_periods as f64)),
        ("offered", Json::num(t_rep.offered as f64)),
        ("delivered", Json::num(t_rep.delivered as f64)),
        ("wall_ms", Json::num(t_wall * 1e3)),
        ("req_per_s", Json::num(t_rep.offered as f64 / t_wall)),
        ("p50_ms", Json::num(t_rep.p50_ms)),
        ("p99_ms", Json::num(t_rep.p99_ms)),
        ("success_rate", Json::num(t_rep.success_rate())),
        ("mean_stretch", Json::num(t_rep.mean_stretch)),
    ]);

    // --- Parallel construction. -----------------------------------------
    for m in [1usize, 8, 32] {
        let mut prng = Rng::new(3);
        let base = random_ring(n, &mut prng);
        let samples = time_iters(1, 5, || {
            dgro::dgro::parallel::parallel_ring(
                &w,
                &base,
                dgro::dgro::parallel::ParallelConfig::new(m),
                |_| Box::new(GreedyScorer),
            )
            .unwrap()
        });
        report(&format!("parallel ring M={m} n=120"), &samples, None);
    }

    // --- Machine-readable trajectory (BENCH_hotpath.json). --------------
    let out = Json::obj(vec![
        ("bench", Json::str("hotpath")),
        ("mode", Json::str(if quick { "quick" } else { "full" })),
        ("threads", Json::num(threads as f64)),
        ("apsp", Json::arr(apsp_rows)),
        ("diameter", Json::arr(diam_rows)),
        ("ga", ga_json),
        ("scenario", scenario_json),
        ("sharded", sharded_json),
        ("net", net_json),
        ("obs", obs_json),
        ("trace", trace_json),
        ("scale", Json::arr(scale_rows)),
        ("traffic", traffic_json),
    ]);
    std::fs::write("BENCH_hotpath.json", out.to_string())?;
    println!("wrote BENCH_hotpath.json (threads={threads} quick={quick})");
    Ok(())
}
