//! `cargo bench --bench hotpath` — micro/meso benchmarks of the L3 hot
//! paths feeding EXPERIMENTS.md §Perf:
//!
//!   * APSP/diameter (the inner loop of every experiment and of the GA)
//!   * ring construction (greedy + native Q-net + PJRT Q-net per step)
//!   * gossip measurement round
//!   * broadcast simulation
//!   * GA evaluation throughput
//!
//! Statistical harness from util::timer/stats (no criterion offline).

use dgro::dgro::construct::{build_ring, GreedyScorer};
use dgro::graph::{apsp, diameter};
use dgro::gossip::measure::{measure, MeasureConfig};
use dgro::latency::Model;
use dgro::qnet::native::NativeQnet;
use dgro::qnet::params::QnetParams;
use dgro::qnet::state::State;
use dgro::qnet::QScorer;
use dgro::runtime::{ArtifactStore, PjrtQnet};
use dgro::sim::broadcast::broadcast_times;
use dgro::topology::genetic::{self, GaConfig};
use dgro::topology::{paper_k, random_ring};
use dgro::util::rng::Rng;
use dgro::util::stats::Summary;
use dgro::util::timer::time_iters;

fn report(name: &str, samples: &[f64], unit_per_iter: Option<(&str, f64)>) {
    let s = Summary::of(samples);
    print!(
        "{name:<44} mean {:>10.4} ms  p50 {:>10.4}  p99 {:>10.4}",
        s.mean * 1e3,
        s.p50 * 1e3,
        s.p99 * 1e3
    );
    if let Some((unit, count)) = unit_per_iter {
        print!("  ({:.1} {unit}/s)", count / s.mean);
    }
    println!();
}

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(0xBEEF);

    // --- APSP / diameter at the paper's scales. ------------------------
    for &n in &[100usize, 300, 1000] {
        let w = Model::Uniform.sample(n, &mut rng);
        let k = paper_k(n);
        let g = dgro::topology::kring::random_krings(n, k, &mut rng)
            .to_graph(&w);
        let iters = if n >= 1000 { 3 } else { 20 };
        let samples = time_iters(2, iters, || diameter::diameter(&g));
        report(&format!("diameter n={n} k={k}"), &samples, None);
        let samples = time_iters(2, iters, || apsp::dijkstra(&g, 0));
        report(&format!("single-source dijkstra n={n}"), &samples, None);
    }

    // --- Ring construction per scorer. ---------------------------------
    let n = 120;
    let w = Model::Fabric.sample(n, &mut rng);
    let samples = time_iters(2, 10, || {
        build_ring(&mut GreedyScorer, &w, 0).unwrap()
    });
    report("ring construction greedy n=120", &samples,
           Some(("steps", n as f64)));

    let mut native = NativeQnet::new(
        ArtifactStore::discover(ArtifactStore::default_dir())
            .and_then(|s| s.load_params())
            .unwrap_or_else(|_| QnetParams::synthetic(16, 32, 7)),
    );
    let samples = time_iters(1, 5, || {
        build_ring(&mut native, &w, 0).unwrap()
    });
    report("ring construction native-qnet n=120", &samples,
           Some(("steps", n as f64)));

    // Single-step scoring latency (the Algorithm-1 inner loop).
    let st = State::new(&w, 0);
    let samples = time_iters(2, 20, || native.score(&st).unwrap());
    report("qnet score (native) n=120", &samples, None);

    match ArtifactStore::discover(ArtifactStore::default_dir())
        .and_then(PjrtQnet::new)
    {
        Ok(mut pjrt) => {
            // Warm the executable cache, then measure steady state.
            let _ = pjrt.score(&st).unwrap();
            let samples = time_iters(2, 20, || pjrt.score(&st).unwrap());
            report("qnet score (pjrt AOT HLO) n=120", &samples, None);
            let samples = time_iters(0, 3, || {
                build_ring(&mut pjrt, &w, 0).unwrap()
            });
            report("ring construction pjrt-qnet n=120", &samples,
                   Some(("steps", n as f64)));
        }
        Err(e) => println!("pjrt benches skipped: {e}"),
    }

    // --- Gossip + broadcast. -------------------------------------------
    let g = dgro::topology::kring::random_krings(n, paper_k(n), &mut rng)
        .to_graph(&w);
    let mut grng = Rng::new(1);
    let samples = time_iters(2, 20, || {
        measure(&w, &g, MeasureConfig::default(), &mut grng)
    });
    report("gossip measurement (Alg 3) n=120", &samples, None);

    let proc = vec![1.0; n];
    let samples = time_iters(2, 50, || broadcast_times(&g, 0, &proc));
    report("broadcast simulation n=120", &samples, None);

    // --- GA throughput (topology evaluations / s). ----------------------
    let budget = 300;
    let mut garng = Rng::new(2);
    let samples = time_iters(0, 3, || {
        genetic::search(
            &w,
            2,
            GaConfig {
                budget,
                ..Default::default()
            },
            &mut garng,
        )
    });
    report("GA search 300 evals n=120 k=2", &samples,
           Some(("evals", budget as f64)));

    // --- Parallel construction. -----------------------------------------
    for m in [1usize, 8, 32] {
        let mut prng = Rng::new(3);
        let base = random_ring(n, &mut prng);
        let samples = time_iters(1, 5, || {
            dgro::dgro::parallel::parallel_ring(
                &w,
                &base,
                dgro::dgro::parallel::ParallelConfig::new(m),
                |_| Box::new(GreedyScorer),
            )
            .unwrap()
        });
        report(&format!("parallel ring M={m} n=120"), &samples, None);
    }
    Ok(())
}
