//! `cargo bench --bench figures` — regenerate every paper figure and
//! write CSVs under reports/. Full mode by default; honor
//! DGRO_BENCH_QUICK=1 for a fast pass. (No criterion offline: this is a
//! plain harness=false bench binary; per-figure wall time is reported.)

use dgro::bench_harness::{run_figure, runner, ALL_FIGURES};

fn main() -> anyhow::Result<()> {
    dgro::util::logging::init_from_env();
    let quick = std::env::var("DGRO_BENCH_QUICK").ok().as_deref() == Some("1")
        // `cargo bench -- quick` also works.
        || std::env::args().any(|a| a == "quick");
    let only: Option<usize> = std::env::args()
        .filter_map(|a| a.strip_prefix("--fig=").and_then(|v| v.parse().ok()))
        .next();

    println!("DGRO figure bench (quick={quick})");
    let mut total = 0.0;
    for fig in ALL_FIGURES {
        if let Some(f) = only {
            if f != fig {
                continue;
            }
        }
        let t0 = std::time::Instant::now();
        match run_figure(fig, quick) {
            Ok(tables) => {
                runner::emit(&tables, "reports")?;
                let dt = t0.elapsed().as_secs_f64();
                total += dt;
                println!("figure {fig:>2}: {dt:8.2}s");
            }
            Err(e) => println!("figure {fig:>2}: SKIP ({e})"),
        }
    }
    println!("total: {total:.1}s — CSVs in reports/");
    Ok(())
}
