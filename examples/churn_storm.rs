//! Scenario-engine demo: the `churn-storm` catalog workload (sustained
//! 5x-baseline Poisson churn over FABRIC-like latencies) run through
//! DGRO's adaptive coordinator and through a static Chord baseline —
//! both fed the SAME latency draw and the SAME churn trace, so the only
//! difference is whether the overlay re-anchors its rings as members
//! come and go.
//!
//!     cargo run --release --example churn_storm
//!
//! The same comparison across the full catalog and baseline panel:
//!     dgro scenario compare --out reports

use dgro::scenario::{find, ScenarioEngine, Topology};

fn main() -> anyhow::Result<()> {
    dgro::util::logging::init_from_env();
    let spec = find("churn-storm")?;
    println!("== scenario {} — {}\n", spec.name, spec.about);

    let engine = ScenarioEngine::new(spec, 7)?;
    let dgro_run = engine.run(Topology::Dgro)?;
    let chord_run = engine.run(Topology::Chord)?;

    println!("--- DGRO (adaptive coordinator) ---");
    print!("{}", dgro_run.render());
    println!("\n--- Chord (static under the same churn) ---");
    print!("{}", chord_run.render());

    println!(
        "\nHEADLINE: mean alive-overlay diameter under churn: \
         dgro {:.2} vs chord {:.2} ({:.2}x), {} ring swaps",
        dgro_run.mean_diameter(),
        chord_run.mean_diameter(),
        dgro_run.mean_diameter() / chord_run.mean_diameter(),
        dgro_run.total_swaps()
    );
    Ok(())
}
