//! Quickstart: sample an IRI-like latency matrix, build overlays, and
//! compare diameters — the 60-second tour of the public API.
//!
//!     cargo run --release --example quickstart

use dgro::dgro::construct::{best_of_starts, GreedyScorer};
use dgro::graph::diameter;
use dgro::latency::Model;
use dgro::topology::{chord::Chord, kring, paper_k, rapid::Rapid};
use dgro::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let n = 119; // 7 nodes per FABRIC site
    let k = paper_k(n);
    let mut rng = Rng::new(42);

    // 1. A latency matrix from the FABRIC-like 17-site model.
    let w = Model::Fabric.sample(n, &mut rng);
    println!("sampled {n}-node FABRIC-like matrix; mean latency {:.1} ms",
             w.mean_offdiag());

    // 2. What deployed systems give you: latency-oblivious overlays.
    let chord = Chord::build(n, &mut rng).to_graph(&w);
    let rapid = Rapid::build(n, &mut rng).to_graph(&w);
    println!("chord  diameter: {:8.1} ms", diameter::diameter(&chord));
    println!("rapid  diameter: {:8.1} ms", diameter::diameter(&rapid));

    // 3. DGRO: the §V adaptive loop — gossip-measure ρ, swap rings
    //    toward the right mix for *this* latency distribution.
    let dgro = dgro::dgro::select::adaptive_krings(&w, k, &mut rng)
        .to_graph(&w);
    println!("dgro   diameter: {:8.1} ms  (adaptive §V, max degree {})",
             diameter::diameter(&dgro), dgro.max_degree());

    // 4. Under the hood that converges to a mostly-shortest hybrid on
    //    clustered latencies:
    let hybrid = kring::hybrid_krings(&w, k, 1, &mut rng).to_graph(&w);
    println!("hybrid diameter: {:8.1} ms (1 random + {} shortest)",
             diameter::diameter(&hybrid), k - 1);

    // 5. Algorithm-1 construction through a scorer (GreedyScorer here;
    //    swap in PjrtQnet::from_default_artifacts() for the learned
    //    policy executing the AOT Pallas kernels).
    let mut scorer = GreedyScorer;
    let (rings, g, d) = best_of_starts(&mut scorer, &w, 2, 10, &mut rng)?;
    println!("2-ring Algorithm-1 build: diameter {d:8.1} ms \
              ({} rings, max degree {})", rings.len(), g.max_degree());
    Ok(())
}
