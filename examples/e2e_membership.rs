//! END-TO-END driver (DESIGN.md §6): a FABRIC-like IRI membership
//! overlay run through the full stack —
//!
//!   1. sample the 17-site latency matrix (~170 controller nodes);
//!   2. boot the coordinator on the latency-oblivious K random rings
//!      (what consistent hashing gives Chord/RAPID);
//!   3. run a churn trace (joins / leaves / crashes) while the §V
//!      adaptive loop measures ρ by gossip and swaps rings;
//!   4. measure what the paper optimizes: overlay diameter, broadcast
//!      (membership-update) propagation latency, and SWIM crash
//!      detection + dissemination time — before vs after DGRO, against
//!      Chord / RAPID / Perigee baselines;
//!   5. if `make artifacts` has run, also build a Q-net ring through the
//!      AOT PJRT path to prove the three-layer stack composes.
//!
//!     cargo run --release --example e2e_membership
//!
//! Results recorded in EXPERIMENTS.md §End-to-end.

use dgro::config::Config;
use dgro::coordinator::Coordinator;
use dgro::graph::{diameter, Graph};
use dgro::latency::{LatencyMatrix, Model};
use dgro::membership::events::EventTrace;
use dgro::membership::swim::{SwimConfig, SwimSim};
use dgro::runtime::{ArtifactStore, PjrtQnet};
use dgro::sim::broadcast::broadcast_times;
use dgro::topology::{chord::Chord, perigee, rapid::Rapid, random_ring};
use dgro::util::rng::Rng;

fn broadcast_stats(g: &Graph, proc: &[f64], rng: &mut Rng) -> (f64, f64) {
    // Mean and worst completion over 10 random sources.
    let mut worst: f64 = 0.0;
    let mut sum = 0.0;
    for _ in 0..10 {
        let src = rng.index(g.n());
        let rep = broadcast_times(g, src, proc);
        worst = worst.max(rep.completion);
        sum += rep.completion;
    }
    (sum / 10.0, worst)
}

fn main() -> anyhow::Result<()> {
    let n = 170; // 10 nodes per FABRIC site
    let horizon = 4000.0; // ms of simulated operation
    let mut rng = Rng::new(20240711);

    println!("=== DGRO end-to-end: {n}-node FABRIC-like IRI overlay ===\n");

    // --- Coordinator with the adaptive loop under churn. -------------
    let mut cfg = Config::default();
    cfg.nodes = n;
    cfg.model = "fabric".into();
    cfg.scorer = "greedy".into();
    cfg.adapt_period_ms = 250.0;
    let mut co = Coordinator::new(cfg.clone())?;
    let w = co.w.clone();
    let proc = vec![1.0f64; n]; // paper: 1 ms processing per node

    let trace = EventTrace::churn(n, horizon, 0.0002, &mut rng);
    println!(
        "churn trace: {} membership events over {horizon} ms",
        trace.len()
    );

    let (b_mean0, b_worst0) =
        broadcast_stats(&co.overlay(), &proc, &mut rng);
    let rep = co.run(&trace, horizon)?;
    let (b_mean1, b_worst1) =
        broadcast_stats(&co.overlay(), &proc, &mut rng);

    println!("\n--- adaptive coordinator (the paper's system) ---");
    println!(
        "overlay diameter : {:9.1} -> {:9.1} ms  ({:+.0}%)",
        rep.initial_diameter,
        rep.final_diameter,
        100.0 * (rep.final_diameter - rep.initial_diameter)
            / rep.initial_diameter
    );
    println!(
        "bcast mean/worst : {b_mean0:9.1} / {b_worst0:9.1} -> \
         {b_mean1:9.1} / {b_worst1:9.1} ms"
    );
    println!(
        "ring swaps: {}   gossip msgs: {}   alive: {}/{n}",
        rep.swaps,
        co.metrics.counter("gossip.messages"),
        rep.alive
    );

    // --- SWIM crash handling on the adapted overlay. ------------------
    let overlay = co.overlay();
    let mut swim = SwimSim::new(&overlay, SwimConfig::default());
    let victim = 42;
    let det = swim.crash_and_measure(victim, &proc, &mut rng);
    println!(
        "SWIM crash node {victim}: detect {:.0} ms, everyone-knows \
         {:.0} ms (dissemination {:.1} ms)",
        det.detect_time, det.everyone_knows, det.dissemination
    );

    // --- Baselines on the same matrix. --------------------------------
    println!("\n--- baselines (same latency matrix) ---");
    let chord = Chord::build(n, &mut rng).to_graph(&w);
    let rapid = Rapid::build(n, &mut rng).to_graph(&w);
    let pg = perigee::build(&w, perigee::PerigeeConfig::default(), &mut rng)
        .union(&random_ring(n, &mut rng).to_graph(&w));
    for (name, g) in
        [("chord", &chord), ("rapid", &rapid), ("perigee+ring", &pg)]
    {
        let (bm, bw) = broadcast_stats(g, &proc, &mut rng);
        println!(
            "{name:<14} diameter {:9.1} ms   bcast mean/worst \
             {bm:9.1}/{bw:9.1} ms",
            diameter::diameter(g)
        );
    }
    let final_d = rep.final_diameter;
    let chord_d = diameter::diameter(&chord);
    println!(
        "\nHEADLINE: DGRO diameter = {:.2}x Chord ({final_d:.0} vs \
         {chord_d:.0} ms)",
        final_d / chord_d
    );

    // --- Three-layer proof: Q-net ring through PJRT. -------------------
    match ArtifactStore::discover(ArtifactStore::default_dir())
        .and_then(PjrtQnet::new)
    {
        Ok(mut qnet) => {
            let small: LatencyMatrix = {
                let mut r2 = Rng::new(5);
                Model::Fabric.sample(119, &mut r2)
            };
            let t0 = std::time::Instant::now();
            let ring =
                dgro::dgro::construct::build_ring(&mut qnet, &small, 0)?;
            let d = diameter::diameter(&ring.to_graph(&small));
            let dt = t0.elapsed().as_secs_f64() * 1e3;
            let mut r2 = Rng::new(17);
            let d_rand = diameter::diameter(
                &random_ring(small.n(), &mut r2).to_graph(&small),
            );
            println!(
                "\nPJRT Q-net single ring (N=119, AOT HLO via xla/PJRT): \
                 diameter {d:.1} ms vs random ring {d_rand:.1} ms \
                 ({:.2}x), built in {dt:.0} ms",
                d / d_rand
            );
        }
        Err(e) => println!("\n(PJRT path skipped: {e})"),
    }
    Ok(())
}
