//! Parallel ring construction (Algorithm 4, §VI): sweep the partition
//! count and show that the diameter holds while sequential steps per
//! worker drop N -> N/M.
//!
//!     cargo run --release --example parallel_build

use dgro::dgro::construct::GreedyScorer;
use dgro::dgro::parallel::{parallel_ring, ParallelConfig};
use dgro::graph::diameter;
use dgro::latency::Model;
use dgro::topology::kring::KRing;
use dgro::topology::{paper_k, random_ring};
use dgro::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let n = 512;
    let k = paper_k(n);
    let mut rng = Rng::new(2024);
    let w = Model::Fabric.sample(n, &mut rng);
    println!("n={n}, k={k} rings, FABRIC latency");
    println!("{:>10} {:>14} {:>18} {:>12}",
             "partitions", "diameter(ms)", "steps/worker", "build(ms)");

    for m in [1usize, 2, 4, 8, 16, 32, 64, 128, 256] {
        let t0 = std::time::Instant::now();
        let mut rings = Vec::with_capacity(k);
        for _ in 0..k {
            let base = random_ring(n, &mut rng);
            rings.push(parallel_ring(
                &w,
                &base,
                ParallelConfig::new(m),
                |_| Box::new(GreedyScorer),
            )?);
        }
        let g = KRing::new(rings).to_graph(&w);
        let dt = t0.elapsed().as_secs_f64() * 1e3;
        println!(
            "{m:>10} {:>14.1} {:>18} {dt:>12.1}",
            diameter::diameter(&g),
            (n + m - 1) / m
        );
    }
    println!("\n(single-core image: the speedup claim is the step-count \
              column; diameter stability is the paper's §VI result)");
    Ok(())
}
