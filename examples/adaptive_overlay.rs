//! Adaptive ring selection (§V) in action: measure ρ with the gossip
//! protocol (Algorithm 3) on differently-shaped overlays under all four
//! latency models, show the decision DGRO takes, and the diameter it
//! buys.
//!
//!     cargo run --release --example adaptive_overlay

use dgro::dgro::select::{decide, materialize, SelectConfig};
use dgro::gossip::measure::{measure, MeasureConfig};
use dgro::graph::diameter;
use dgro::latency::Model;
use dgro::topology::{random_ring, shortest_ring};
use dgro::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let n = 102;
    for model in Model::ALL {
        println!("=== latency model: {} ===", model.name());
        let mut rng = Rng::new(7);
        let w = model.sample(n, &mut rng);

        for (name, g) in [
            ("random ring (Chord-like)",
             random_ring(n, &mut rng).to_graph(&w)),
            ("shortest ring (Perigee-like)",
             shortest_ring(&w, 0).to_graph(&w)),
        ] {
            let stats = measure(&w, &g, MeasureConfig::default(), &mut rng);
            let choice = decide(&stats, SelectConfig::default());
            let d0 = diameter::diameter(&g);
            print!(
                "  {name:<30} rho={:.2} diameter={d0:9.1} -> {choice:?}",
                stats.rho()
            );
            // Apply the decision: union the selected companion ring.
            if let Some(extra) = materialize(choice, &w, 0, &mut rng) {
                let g2 = g.union(&extra.to_graph(&w));
                let d1 = diameter::diameter(&g2);
                println!(" => diameter {d1:9.1} ({:+.0}%)",
                         100.0 * (d1 - d0) / d0);
            } else {
                println!(" (kept)");
            }
        }
    }
    Ok(())
}
