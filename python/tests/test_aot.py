"""AOT export checks: the HLO text artifact must parse back through XLA,
carry the canonical 14-parameter signature, and stay numerically equal to
the oracle through the export wrapper. (Execution of the artifact itself is
covered by the Rust integration tests in rust/tests/runtime_roundtrip.rs,
which load these files through the same PJRT CPU client.)"""

import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model

from .test_kernels import rand_params, rand_state


def test_bucket_export_smoke(tmp_path):
    out = tmp_path / "qnet_16.hlo.txt"
    size = aot.export_bucket(16, str(out))
    text = out.read_text()
    assert size == len(text) > 1000
    assert "HloModule" in text
    # 10 thetas + W + A + deg + vcur + wscale + wmean = 16 parameters in the ENTRY
    # computation (sub-computations from the pallas lowering have their
    # own parameter instructions, so restrict to the ENTRY block).
    entry = text[text.index("ENTRY"):]
    assert entry.count("parameter(") == 16


def test_exported_hlo_parses_back_through_xla(tmp_path):
    """hlo_module_from_text is the same text parser xla_extension 0.5.1
    exposes to the rust crate; if it accepts the artifact here, the Rust
    loader will too (ids get reassigned by the parser)."""
    out = tmp_path / "qnet_32.hlo.txt"
    aot.export_bucket(32, str(out))
    mod = xc._xla.hlo_module_from_text(out.read_text())
    text2 = mod.to_string()
    assert "HloModule" in text2
    # Round-tripped module keeps the entry signature (count parameters).
    assert text2.count("parameter(") >= 16


@pytest.mark.parametrize("n", [16, 64])
def test_qnet_for_export_signature(n):
    import jax.numpy as jnp

    params = rand_params(22)
    W, A, deg, vcur, _ = rand_state(7, n)
    wscale = model.default_wscale(W)
    wmean = model.default_wmean(W)
    args = model.flatten_params(params) + [W, A, deg, vcur, wscale, wmean]
    (q,) = aot.qnet_for_export(*args)
    want = model.qnet_forward(params, W, A, deg, vcur, use_pallas=True)
    np.testing.assert_allclose(q, want, rtol=1e-6, atol=1e-6)


def test_padding_to_bucket_preserves_q_values():
    """The contract the Rust runtime relies on: embed an N-node graph in a
    larger N'-bucket (zero-padded W/A/deg/vcur) and pass the *unpadded*
    wscale — the Q-values of the real nodes must match the unpadded run
    exactly (pad nodes keep mu = 0 and only enter via mean(W), which the
    explicit wscale overrides)."""
    import jax.numpy as jnp

    params = rand_params(23)
    n, npad = 20, 32
    W, A, deg, vcur, _ = rand_state(55, n)
    wscale = model.default_wscale(W)
    wmean = model.default_wmean(W)
    want = model.qnet_forward(params, W, A, deg, vcur, wscale, wmean)

    Wp = jnp.zeros((npad, npad), jnp.float32).at[:n, :n].set(W)
    Ap = jnp.zeros((npad, npad), jnp.float32).at[:n, :n].set(A)
    degp = jnp.zeros((npad,), jnp.float32).at[:n].set(deg)
    vcurp = jnp.zeros((npad,), jnp.float32).at[:n].set(vcur)
    got = model.qnet_forward(params, Wp, Ap, degp, vcurp, wscale, wmean)
    np.testing.assert_allclose(got[:n], want, rtol=1e-5, atol=1e-5)


def test_hlo_text_is_deterministic(tmp_path):
    """Same bucket exported twice must be byte-identical (hermetic builds:
    `make artifacts` no-op correctness relies on it)."""
    a = tmp_path / "a.txt"
    b = tmp_path / "b.txt"
    aot.export_bucket(16, str(a))
    aot.export_bucket(16, str(b))
    assert a.read_text() == b.read_text()


def test_buckets_cover_paper_qnet_regime():
    """Paper SV: Q-learning regime tops out around N=200; our largest
    bucket must cover it, and buckets must be sorted for pad-to-bucket."""
    assert max(aot.BUCKETS) >= 200
    assert list(aot.BUCKETS) == sorted(aot.BUCKETS)
