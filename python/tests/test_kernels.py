"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

These tests are the core correctness signal for the compute layer: every
HLO artifact the Rust runtime executes is built from these kernels, so
`embed_iter == embed_iter_ref` and `qhead == qhead_ref` (to f32 tolerance)
is what makes the whole stack trustworthy. Hypothesis sweeps sizes, tile
splits, and value ranges.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import embed, qhead, ref
from compile import model

P = model.EMBED_DIM
H = model.HIDDEN_DIM


def rand_params(seed: int, p: int = P, h: int = H):
    key = jax.random.PRNGKey(seed)
    return model.init_params(key, p, h)


def rand_state(seed: int, n: int):
    """Random (W, A, deg, vcur, mu): symmetric W>0, path-like A."""
    rng = np.random.default_rng(seed)
    w = rng.uniform(1.0, 10.0, size=(n, n)).astype(np.float32)
    w = np.triu(w, 1)
    w = w + w.T
    a = np.zeros((n, n), dtype=np.float32)
    order = rng.permutation(n)
    for i in range(n - 1):
        u, v = order[i], order[i + 1]
        a[u, v] = a[v, u] = 1.0
    deg = a.sum(axis=1).astype(np.float32)
    vcur = np.zeros(n, dtype=np.float32)
    vcur[order[-1]] = 1.0
    mu = rng.normal(size=(n, P)).astype(np.float32)
    return (jnp.asarray(w), jnp.asarray(a), jnp.asarray(deg),
            jnp.asarray(vcur), jnp.asarray(mu))


@pytest.mark.parametrize("n", [16, 32, 64, 128, 256])
def test_embed_matches_ref(n):
    params = rand_params(0)
    W, A, deg, vcur, mu = rand_state(n, n)
    got = embed.embed_iter(A, W, mu, deg, params["t1"], params["t2"],
                           params["t3"], params["t4"])
    want = ref.embed_iter_ref(A, W, mu, deg, params["t1"], params["t2"],
                              params["t3"], params["t4"])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,block", [(32, 8), (32, 16), (64, 32), (128, 64)])
def test_embed_tiling_invariance(n, block):
    """Result must not depend on the BlockSpec row-tile size."""
    params = rand_params(1)
    W, A, deg, vcur, mu = rand_state(n + 1, n)
    full = embed.embed_iter(A, W, mu, deg, params["t1"], params["t2"],
                            params["t3"], params["t4"], block_n=n)
    tiled = embed.embed_iter(A, W, mu, deg, params["t1"], params["t2"],
                             params["t3"], params["t4"], block_n=block)
    np.testing.assert_allclose(tiled, full, rtol=1e-5, atol=1e-5)


def test_embed_rejects_bad_tile():
    params = rand_params(2)
    W, A, deg, vcur, mu = rand_state(3, 32)
    with pytest.raises(ValueError):
        embed.embed_iter(A, W, mu, deg, params["t1"], params["t2"],
                         params["t3"], params["t4"], block_n=7)


@pytest.mark.parametrize("n", [16, 32, 64, 128, 256])
def test_qhead_matches_ref(n):
    params = rand_params(3)
    W, A, deg, vcur, mu = rand_state(n + 7, n)
    wrow = vcur @ W
    got = qhead.qhead(mu, wrow, vcur, params["t5"], params["t6"],
                      params["t7"], params["t8"], params["t9"],
                      params["t10"])
    want = ref.qhead_ref(mu, wrow, vcur, params["t5"], params["t6"],
                         params["t7"], params["t8"], params["t9"],
                         params["t10"])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,block", [(32, 8), (64, 16), (128, 32)])
def test_qhead_tiling_invariance(n, block):
    params = rand_params(4)
    W, A, deg, vcur, mu = rand_state(n + 13, n)
    wrow = vcur @ W
    full = qhead.qhead(mu, wrow, vcur, params["t5"], params["t6"],
                       params["t7"], params["t8"], params["t9"],
                       params["t10"], block_n=n)
    tiled = qhead.qhead(mu, wrow, vcur, params["t5"], params["t6"],
                        params["t7"], params["t8"], params["t9"],
                        params["t10"], block_n=block)
    np.testing.assert_allclose(tiled, full, rtol=1e-4, atol=1e-4)


def test_latency_term_positive_weights_closed_form():
    """With all-positive W, R[v,k] collapses to relu(t4[k]) * rowsum(W)[v];
    the kernel must honour that identity (sanity of the relu gating)."""
    rng = np.random.default_rng(11)
    n = 32
    w = jnp.asarray(rng.uniform(0.5, 5.0, size=(n, n)).astype(np.float32))
    t4 = jnp.asarray(rng.normal(size=(P,)).astype(np.float32))
    got = ref.latency_term_ref(w, t4)
    want = jnp.maximum(t4, 0.0)[None, :] * w.sum(axis=1)[:, None]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@hypothesis.settings(max_examples=20, deadline=None)
@hypothesis.given(
    n=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**16),
    scale=st.floats(0.1, 50.0),
)
def test_embed_hypothesis_sweep(n, seed, scale):
    """Property: Pallas == oracle across sizes, seeds, and latency scales."""
    params = rand_params(seed % 97)
    W, A, deg, vcur, mu = rand_state(seed, n)
    W = W * jnp.float32(scale)
    got = embed.embed_iter(A, W, mu, deg, params["t1"], params["t2"],
                           params["t3"], params["t4"])
    want = ref.embed_iter_ref(A, W, mu, deg, params["t1"], params["t2"],
                              params["t3"], params["t4"])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@hypothesis.settings(max_examples=20, deadline=None)
@hypothesis.given(n=st.sampled_from([16, 32, 64]), seed=st.integers(0, 2**16))
def test_qhead_hypothesis_sweep(n, seed):
    params = rand_params((seed + 1) % 89)
    W, A, deg, vcur, mu = rand_state(seed, n)
    wrow = vcur @ W
    got = qhead.qhead(mu, wrow, vcur, params["t5"], params["t6"],
                      params["t7"], params["t8"], params["t9"],
                      params["t10"])
    want = ref.qhead_ref(mu, wrow, vcur, params["t5"], params["t6"],
                         params["t7"], params["t8"], params["t9"],
                         params["t10"])
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_embed_all_zero_state():
    """Empty partial solution: embedding must still be finite and the
    theta3 latency term must dominate (A@mu == 0, deg == 0)."""
    params = rand_params(5)
    n = 16
    rng = np.random.default_rng(0)
    w = rng.uniform(1, 10, size=(n, n)).astype(np.float32)
    w = np.triu(w, 1)
    w = jnp.asarray(w + w.T)
    a = jnp.zeros((n, n), jnp.float32)
    deg = jnp.zeros((n,), jnp.float32)
    mu = jnp.zeros((n, P), jnp.float32)
    out = embed.embed_iter(a, w, mu, deg, params["t1"], params["t2"],
                           params["t3"], params["t4"])
    want = ref.embed_iter_ref(a, w, mu, deg, params["t1"], params["t2"],
                              params["t3"], params["t4"])
    assert bool(jnp.isfinite(out).all())
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)
    assert float(jnp.abs(out).sum()) > 0.0
