"""Incremental-APSP reward substrate: add_edge vs Floyd-Warshall oracle."""

import hypothesis
import hypothesis.strategies as st
import numpy as np

from compile import diameter


def random_edges(rng, n, m):
    seen = set()
    edges = []
    while len(edges) < m:
        u, v = rng.integers(0, n, 2)
        if u == v or (min(u, v), max(u, v)) in seen:
            continue
        seen.add((min(u, v), max(u, v)))
        edges.append((int(u), int(v), float(rng.integers(1, 11))))
    return edges


@hypothesis.settings(max_examples=30, deadline=None)
@hypothesis.given(n=st.integers(4, 24), m_frac=st.floats(0.1, 1.0),
                  seed=st.integers(0, 2**16))
def test_incremental_apsp_matches_floyd_warshall(n, m_frac, seed):
    rng = np.random.default_rng(seed)
    max_m = n * (n - 1) // 2
    m = max(1, int(m_frac * max_m))
    edges = random_edges(rng, n, m)

    dist = diameter.fresh_dist(n)
    weights = np.zeros((n, n))
    adj = np.zeros((n, n))
    for u, v, w in edges:
        diameter.add_edge(dist, u, v, w)
        # Keep min weight under accidental parallel proposals.
        if adj[u, v] == 0 or w < weights[u, v]:
            weights[u, v] = weights[v, u] = w
        adj[u, v] = adj[v, u] = 1

    want = diameter.floyd_warshall(weights, adj)
    finite = np.isfinite(want)
    np.testing.assert_allclose(dist[finite], want[finite], rtol=0, atol=1e-9)
    assert np.array_equal(np.isfinite(dist), finite)


def test_largest_cc_diameter_picks_largest_component():
    # Two components: a 3-path (sizes 3, diam 2+3=5) and an edge (size 2).
    dist = diameter.fresh_dist(5)
    diameter.add_edge(dist, 0, 1, 2.0)
    diameter.add_edge(dist, 1, 2, 3.0)
    diameter.add_edge(dist, 3, 4, 100.0)
    assert diameter.largest_cc_diameter(dist) == 5.0


def test_empty_graph_diameter_zero():
    dist = diameter.fresh_dist(6)
    assert diameter.largest_cc_diameter(dist) == 0.0


def test_add_edge_no_improvement_is_noop():
    dist = diameter.fresh_dist(3)
    diameter.add_edge(dist, 0, 1, 1.0)
    before = dist.copy()
    diameter.add_edge(dist, 0, 1, 5.0)  # worse parallel edge
    np.testing.assert_array_equal(dist, before)


def test_ring_diameter_exact():
    """Unit-weight N-ring has diameter floor(N/2)."""
    n = 8
    dist = diameter.fresh_dist(n)
    for i in range(n):
        diameter.add_edge(dist, i, (i + 1) % n, 1.0)
    assert diameter.largest_cc_diameter(dist) == n // 2
