"""L2 correctness: full Q-network forward, Pallas path vs oracle path,
parameter plumbing, and the DQN loss/step machinery."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

from .test_kernels import rand_params, rand_state


@pytest.mark.parametrize("n", [16, 32, 64, 128])
def test_forward_pallas_matches_oracle(n):
    """use_pallas=True and use_pallas=False must agree: this is exactly the
    computation the AOT artifact freezes for Rust."""
    params = rand_params(10)
    W, A, deg, vcur, _ = rand_state(n * 3 + 1, n)
    q_pallas = model.qnet_forward(params, W, A, deg, vcur, use_pallas=True)
    q_ref = model.qnet_forward(params, W, A, deg, vcur, use_pallas=False)
    assert q_pallas.shape == (n,)
    np.testing.assert_allclose(q_pallas, q_ref, rtol=1e-4, atol=1e-4)


def test_forward_matches_standalone_ref():
    params = rand_params(11)
    W, A, deg, vcur, _ = rand_state(42, 32)
    got = model.qnet_forward(params, W, A, deg, vcur)
    want = ref.qnet_forward_ref(params, W, A, deg, vcur,
                                n_iters=model.N_ITERS)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_param_shapes_and_roundtrip():
    params = rand_params(12)
    shapes = model.param_shapes()
    for name in model.PARAM_ORDER:
        assert params[name].shape == shapes[name], name
    leaves = model.flatten_params(params)
    assert len(leaves) == 10
    back = model.unflatten_params(leaves)
    for name in model.PARAM_ORDER:
        np.testing.assert_array_equal(back[name], params[name])


def test_forward_is_deterministic():
    params = rand_params(13)
    W, A, deg, vcur, _ = rand_state(5, 16)
    q1 = model.qnet_forward(params, W, A, deg, vcur)
    q2 = model.qnet_forward(params, W, A, deg, vcur)
    np.testing.assert_array_equal(q1, q2)


def test_forward_finite_on_extreme_latency():
    params = rand_params(14)
    n = 16
    W = jnp.full((n, n), 1e4, jnp.float32) * (1 - jnp.eye(n, dtype=jnp.float32))
    A = jnp.zeros((n, n), jnp.float32)
    deg = jnp.zeros((n,), jnp.float32)
    vcur = jnp.zeros((n,), jnp.float32).at[0].set(1.0)
    q = model.qnet_forward(params, W, A, deg, vcur)
    assert bool(jnp.isfinite(q).all())


def make_batch(seed: int, b: int, n: int):
    rng = np.random.default_rng(seed)
    batch = {}
    ws = []
    for _ in range(b):
        w = rng.uniform(1, 10, size=(n, n)).astype(np.float32)
        w = np.triu(w, 1)
        ws.append(w + w.T)
    batch["W"] = jnp.asarray(np.stack(ws))
    a = (rng.random((b, n, n)) < 0.1).astype(np.float32)
    a = np.triu(a, 1)
    a = a + np.transpose(a, (0, 2, 1))
    batch["A"] = jnp.asarray(a)
    batch["deg"] = jnp.asarray(a.sum(axis=2).astype(np.float32))
    vcur = np.zeros((b, n), np.float32)
    vcur[np.arange(b), rng.integers(0, n, b)] = 1.0
    batch["vcur"] = jnp.asarray(vcur)
    batch["action"] = jnp.asarray(rng.integers(0, n, b).astype(np.int32))
    batch["reward"] = jnp.asarray(rng.normal(size=b).astype(np.float32))
    batch["A_next"] = batch["A"]
    batch["deg_next"] = batch["deg"]
    batch["vcur_next"] = batch["vcur"]
    mask = (rng.random((b, n)) < 0.5).astype(np.float32)
    mask[:, 0] = 1.0  # ensure at least one selectable successor
    batch["mask_next"] = jnp.asarray(mask)
    batch["done"] = jnp.asarray(
        (rng.random(b) < 0.2).astype(np.float32))
    return batch


def test_td_loss_finite_and_positive():
    params = rand_params(15)
    batch = make_batch(0, 8, 16)
    loss = model.td_loss(params, params, batch, gamma=0.9)
    assert bool(jnp.isfinite(loss))
    assert float(loss) >= 0.0


def test_sgd_step_reduces_loss_on_fixed_batch():
    """A few steps on one fixed batch must strictly reduce the TD loss
    (target net held constant), proving gradients flow through both the
    embedding and the head."""
    params = rand_params(16)
    target = params
    batch = make_batch(1, 16, 16)
    loss0 = float(model.td_loss(params, target, batch, gamma=0.9))
    step = jax.jit(lambda p, t, b: model.sgd_step(p, t, b, lr=1e-3, gamma=0.9))
    p = params
    for _ in range(20):
        p, loss = step(p, target, batch)
    assert float(loss) < loss0


def test_td_loss_terminal_states_ignore_bootstrap():
    """done=1 rows must not use Q(S'): loss equals (r - Q(s,a))^2 there."""
    params = rand_params(17)
    batch = make_batch(2, 4, 16)
    batch["done"] = jnp.ones(4, jnp.float32)
    # Zero mask as well: even with no successor the loss must stay finite.
    batch["mask_next"] = jnp.zeros((4, 16), jnp.float32)
    loss = model.td_loss(params, params, batch, gamma=0.9)
    assert bool(jnp.isfinite(loss))
