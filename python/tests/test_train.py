"""DQN trainer smoke + environment invariants (kept cheap for CI)."""

import json

import numpy as np

from compile import model, train


def test_make_graph_symmetric_zero_diag():
    rng = np.random.default_rng(0)
    w = train.make_graph(rng, 12)
    assert w.shape == (12, 12)
    np.testing.assert_array_equal(w, w.T)
    assert np.all(np.diag(w) == 0)
    off = w[~np.eye(12, dtype=bool)]
    assert off.min() >= 1 and off.max() <= 10


def test_episode_builds_hamiltonian_ring():
    rng = np.random.default_rng(1)
    w = train.make_graph(rng, 10)
    ep = train.Episode(w, start=3, alpha=0.05)
    order = [3]
    while not ep.done():
        cand = np.flatnonzero(~ep.visited)
        nxt = int(rng.choice(cand))
        ep.step(nxt)
        order.append(nxt)
    assert sorted(order) == list(range(10))
    # Every node has degree exactly 2 in a closed ring.
    np.testing.assert_array_equal(ep.deg, np.full(10, 2.0))
    assert ep.A.sum() == 2 * 10  # N undirected edges
    assert ep.diam > 0


def test_episode_reward_telescopes_to_final_diameter():
    """sum of diameter deltas == -D(G_T) (paper SIV-C), modulo the alpha
    term and the scale normalization (rewards are divided by mean(W) so
    Q-value scales match the scale-invariant forward pass)."""
    rng = np.random.default_rng(2)
    w = train.make_graph(rng, 8)
    alpha = 0.0
    ep = train.Episode(w, start=0, alpha=alpha)
    total = 0.0
    while not ep.done():
        cand = np.flatnonzero(~ep.visited)
        total += ep.step(int(cand[0]))
    wbar = w.sum() / (8 * 7)
    assert abs(total * wbar - (0.0 - ep.diam)) < 1e-6


def test_replay_fifo_and_sample_shapes():
    rep = train.Replay(capacity=8, n=4)
    for i in range(10):
        rep.push(W=np.full((4, 4), i, np.float32),
                 A=np.zeros((4, 4), np.float32),
                 deg=np.zeros(4, np.float32), vcur=np.zeros(4, np.float32),
                 action=i % 4, reward=float(i),
                 A_next=np.zeros((4, 4), np.float32),
                 deg_next=np.zeros(4, np.float32),
                 vcur_next=np.zeros(4, np.float32),
                 mask_next=np.ones(4, np.float32), done=0.0)
    assert rep.size == 8
    rng = np.random.default_rng(0)
    batch = rep.sample(rng, 5)
    assert batch["W"].shape == (5, 4, 4)
    assert batch["action"].shape == (5,)
    # FIFO: entries 0 and 1 were overwritten by 8 and 9.
    assert float(rep.W[0, 0, 0]) == 8.0


def test_train_smoke_and_weight_roundtrip(tmp_path):
    """Tiny run must complete, emit a curve, and the weight JSON must
    round-trip exactly (this file is what Rust parses)."""
    params, curve = train.train(
        n=8, episodes=6, batch=8, eval_every=3, eval_graphs=1,
        eps_decay=4, seed=0, log=lambda *a, **k: None)
    assert len(curve) >= 2
    for ep_i, eps, train_d, test_d, loss in curve:
        assert np.isfinite(test_d) and test_d > 0

    path = tmp_path / "w.json"
    train.save_weights(params, str(path))
    with open(path) as f:
        payload = json.load(f)
    assert payload["format"] == "dgro-qnet-v1"
    assert payload["embed_dim"] == model.EMBED_DIM
    loaded = train.load_weights(str(path))
    for name in model.PARAM_ORDER:
        np.testing.assert_allclose(loaded[name], params[name],
                                   rtol=1e-6, atol=1e-7)


def test_greedy_rollout_valid_ring():
    import jax
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    w = train.make_graph(rng, 8)
    q_fn = jax.jit(lambda p, W, A, d, v: model.qnet_forward(p, W, A, d, v))
    d = train.greedy_rollout(params, w, 0, 0.05, q_fn)
    assert np.isfinite(d) and d > 0
