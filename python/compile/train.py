"""DQN training for the DGRO Q-network (paper Algorithm 2, SIV-E).

Build-time only: this script runs once under ``make artifacts`` and emits

  artifacts/qnet_weights.json   -- trained thetas (consumed by Rust)
  artifacts/training_curve.csv  -- Fig-9 reproduction (epoch, train/test D)

Training setup mirrors SVII-B1 scaled to this image's single CPU core:
graphs are N-node complete graphs with i.i.d. Uniform{1..10} latencies;
an episode builds one ring by epsilon-greedy node selection; the reward is
r = D(G_t) - D(G_{t+1}) - alpha * w(a_t, a_{t+1}); replay memory feeds
1-step TD updates (model.sgd_step). Epsilon decays linearly, exactly the
paper's max(1 - epoch/decay, 0.05) schedule.

Incremental APSP (diameter.add_edge) keeps the reward at O(N^2)/step.
"""

from __future__ import annotations

import argparse
import csv
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import diameter, model


def make_graph(rng: np.random.Generator, n: int) -> np.ndarray:
    """Symmetric N x N latency matrix, entries Uniform{1..10}, zero diag."""
    w = rng.integers(1, 11, size=(n, n)).astype(np.float32)
    w = np.triu(w, 1)
    w = w + w.T
    return w


class Episode:
    """State of one ring-construction episode (environment side)."""

    def __init__(self, W: np.ndarray, start: int, alpha: float):
        self.W = W
        self.n = W.shape[0]
        self.alpha = alpha
        self.A = np.zeros((self.n, self.n), dtype=np.float32)
        self.deg = np.zeros(self.n, dtype=np.float32)
        self.visited = np.zeros(self.n, dtype=bool)
        self.visited[start] = True
        self.cur = start
        self.start = start
        self.dist = diameter.fresh_dist(self.n)
        self.diam = 0.0
        self.order = [start]

    def mask(self) -> np.ndarray:
        """1.0 where a node is still selectable as the next ring hop."""
        return (~self.visited).astype(np.float32)

    def vcur(self) -> np.ndarray:
        v = np.zeros(self.n, dtype=np.float32)
        v[self.cur] = 1.0
        return v

    def done(self) -> bool:
        return bool(self.visited.all())

    def step(self, nxt: int) -> float:
        """Add edge (cur -> nxt); returns the paper's shaped reward,
        normalized by the graph's mean latency so Q-value scales are
        comparable across latency distributions (the forward pass is
        scale-invariant, so rewards must be too)."""
        w = float(self.W[self.cur, nxt])
        self._add(self.cur, nxt)
        reward_edge = w
        self.visited[nxt] = True
        self.cur = nxt
        self.order.append(nxt)
        if self.done():
            # Close the ring back to the start node.
            reward_edge += float(self.W[self.cur, self.start])
            self._add(self.cur, self.start)
        new_diam = diameter.largest_cc_diameter(self.dist)
        r = (self.diam - new_diam) - self.alpha * reward_edge
        self.diam = new_diam
        wbar = float(self.W.sum()) / (self.n * (self.n - 1))
        return r / max(wbar, 1e-6)

    def _add(self, u: int, v: int) -> None:
        self.A[u, v] = 1.0
        self.A[v, u] = 1.0
        self.deg[u] += 1.0
        self.deg[v] += 1.0
        diameter.add_edge(self.dist, u, v, float(self.W[u, v]))


class Replay:
    """Fixed-capacity FIFO replay memory of stacked transitions."""

    def __init__(self, capacity: int, n: int):
        self.capacity = capacity
        self.n = n
        self.size = 0
        self.pos = 0
        self.W = np.zeros((capacity, n, n), dtype=np.float32)
        self.A = np.zeros((capacity, n, n), dtype=np.float32)
        self.deg = np.zeros((capacity, n), dtype=np.float32)
        self.vcur = np.zeros((capacity, n), dtype=np.float32)
        self.action = np.zeros(capacity, dtype=np.int32)
        self.reward = np.zeros(capacity, dtype=np.float32)
        self.A_next = np.zeros((capacity, n, n), dtype=np.float32)
        self.deg_next = np.zeros((capacity, n), dtype=np.float32)
        self.vcur_next = np.zeros((capacity, n), dtype=np.float32)
        self.mask_next = np.zeros((capacity, n), dtype=np.float32)
        self.done = np.zeros(capacity, dtype=np.float32)

    def push(self, **kw) -> None:
        i = self.pos
        for name, val in kw.items():
            getattr(self, name)[i] = val
        self.pos = (self.pos + 1) % self.capacity
        self.size = min(self.size + 1, self.capacity)

    def sample(self, rng: np.random.Generator, batch: int) -> dict:
        idx = rng.integers(0, self.size, size=batch)
        return {
            name: jnp.asarray(getattr(self, name)[idx])
            for name in ("W", "A", "deg", "vcur", "action", "reward",
                         "A_next", "deg_next", "vcur_next", "mask_next",
                         "done")
        }


def greedy_rollout(params, W: np.ndarray, start: int, alpha: float,
                   q_fn) -> float:
    """Build one ring greedily with the current Q-net; returns its diameter."""
    ep = Episode(W, start, alpha)
    while not ep.done():
        q = np.array(q_fn(params, jnp.asarray(W), jnp.asarray(ep.A),
                            jnp.asarray(ep.deg), jnp.asarray(ep.vcur())))
        q[ep.visited] = -np.inf
        ep.step(int(np.argmax(q)))
    return ep.diam


def random_partial_state(rng: np.random.Generator, n: int):
    """A random mid-construction state (W, A, deg, vcur, visited)."""
    w = make_graph(rng, n)
    ep = Episode(w, int(rng.integers(n)), 0.0)
    steps = int(rng.integers(0, n - 1))
    for _ in range(steps):
        cand = np.flatnonzero(~ep.visited)
        ep.step(int(rng.choice(cand)))
    return w, ep


def warmup(params, steps: int = 1500, n: int = 20, batch: int = 16,
           lr: float = 3e-4, scale: float = 3.0, seed: int = 11,
           log=print):
    """Imitation warm-start: regress Q(S, u) toward the nearest-neighbour
    heuristic's score -scale * w(v_t, u)/mean(W) on random partial
    states. After this, greedy rollouts reproduce the shortest-ring
    heuristic; the DQN phase then fine-tunes toward the diameter
    objective (the paper's hybrid of human heuristics + RL, SI)."""
    rng = np.random.default_rng(seed)

    def loss_fn(p, Ws, As, degs, vcurs):
        def one(W, A, deg, vcur):
            q = model.qnet_forward(p, W, A, deg, vcur)
            wrow = vcur @ W
            wbar = jnp.mean(W) * (W.shape[0] ** 2) / \
                (W.shape[0] * (W.shape[0] - 1))
            target = -scale * wrow / wbar
            return jnp.mean((q - target) ** 2)
        return jnp.mean(jax.vmap(one)(Ws, As, degs, vcurs))

    @jax.jit
    def step_fn(p, Ws, As, degs, vcurs):
        loss, grads = jax.value_and_grad(loss_fn)(p, Ws, As, degs, vcurs)
        gnorm = jnp.sqrt(sum(
            jnp.sum(g * g) for g in jax.tree_util.tree_leaves(grads)))
        clip = jnp.minimum(1.0, model.GRAD_CLIP_NORM / (gnorm + 1e-8))
        new_p = jax.tree_util.tree_map(
            lambda w, g: w - lr * clip * g, p, grads)
        return new_p, loss

    for step in range(steps):
        Ws, As, degs, vcurs = [], [], [], []
        for _ in range(batch):
            w, ep = random_partial_state(rng, n)
            Ws.append(w)
            As.append(ep.A.copy())
            degs.append(ep.deg.copy())
            vcurs.append(ep.vcur())
        params, loss = step_fn(
            params, jnp.asarray(np.stack(Ws)), jnp.asarray(np.stack(As)),
            jnp.asarray(np.stack(degs)), jnp.asarray(np.stack(vcurs)))
        if step % 300 == 0:
            log(f"warmup {step:5d} loss={float(loss):9.4f}")
    return params


def train(n: int = 20, episodes: int = 400, batch: int = 32,
          lr: float = 5e-4, gamma: float = 0.99, alpha: float = 0.3,
          eps_decay: int = 1200, replay_cap: int = 20000,
          target_sync: int = 50, eval_every: int = 25, eval_graphs: int = 4,
          n_step: int = 5, warmup_steps: int = 1500, seed: int = 7,
          log=print) -> tuple:
    """Run Algorithm 2; returns (params, curve) where curve is a list of
    (episode, epsilon, train_diam, test_diam, loss) rows.

    Uses n-step returns (Algorithm 2's "if t >= n" line, following Khalil
    et al. 2017): the stored transition is
    (S_t, a_t, sum_{i<n} gamma^i r_{t+i}, S_{t+n}), bootstrapped with
    gamma^n — this propagates the end-of-episode diameter signal through
    the N-step horizon far faster than 1-step TD."""
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    params = model.init_params(key)
    if warmup_steps > 0:
        params = warmup(params, steps=warmup_steps, n=n, seed=seed, log=log)
    target = params

    boot_gamma = gamma ** n_step
    q_fn = jax.jit(lambda p, W, A, d, v: model.qnet_forward(p, W, A, d, v))
    step_fn = jax.jit(
        lambda p, t, b: model.sgd_step(p, t, b, lr=lr, gamma=boot_gamma))

    replay = Replay(replay_cap, n)
    eval_set = [make_graph(rng, n) for _ in range(eval_graphs)]
    curve = []
    losses = []
    t0 = time.time()
    best_params, best_test = params, float("inf")
    # After an imitation warm-start the policy is already strong; explore
    # gently so fine-tuning refines rather than destroys it.
    eps_max = 0.3 if warmup_steps > 0 else 1.0

    for episode in range(1, episodes + 1):
        eps = max(eps_max * (1.0 - episode / eps_decay), 0.05)
        W = make_graph(rng, n)
        ep = Episode(W, int(rng.integers(n)), alpha)
        # Sliding window of the last n_step (state, action, reward)s.
        window = []
        while not ep.done():
            state = (ep.A.copy(), ep.deg.copy(), ep.vcur())
            if rng.random() < eps:
                cand = np.flatnonzero(~ep.visited)
                action = int(rng.choice(cand))
            else:
                q = np.array(q_fn(params, jnp.asarray(W),
                                  jnp.asarray(ep.A), jnp.asarray(ep.deg),
                                  jnp.asarray(ep.vcur())))
                q[ep.visited] = -np.inf
                action = int(np.argmax(q))
            r = ep.step(action)
            window.append((state, action, r))
            done_now = ep.done()
            # Emit the n-step transition whose horizon just completed
            # (and flush the whole window at episode end).
            flush = [len(window) - n_step] if not done_now else \
                range(max(0, len(window) - n_step), len(window))
            for idx in flush:
                if idx < 0:
                    continue
                (s0, a0, _) = window[idx]
                ret = 0.0
                for j, (_, _, rj) in enumerate(window[idx:]):
                    ret += (gamma ** j) * rj
                replay.push(
                    W=W, A=s0[0], deg=s0[1], vcur=s0[2],
                    action=a0, reward=ret,
                    A_next=ep.A.copy(), deg_next=ep.deg.copy(),
                    vcur_next=ep.vcur(), mask_next=ep.mask(),
                    done=1.0 if done_now else 0.0)
            if replay.size >= batch:
                b = replay.sample(rng, batch)
                params, loss = step_fn(params, target, b)
                losses.append(float(loss))
        if episode % target_sync == 0:
            target = params
        if episode % eval_every == 0 or episode == episodes:
            test_d = float(np.mean([
                greedy_rollout(params, Wt, 0, alpha, q_fn)
                for Wt in eval_set]))
            if test_d < best_test:
                best_test = test_d
                best_params = params
            mean_loss = float(np.mean(losses[-200:])) if losses else 0.0
            curve.append((episode, eps, ep.diam, test_d, mean_loss))
            log(f"ep {episode:5d} eps={eps:.2f} train_D={ep.diam:6.1f} "
                f"test_D={test_d:6.1f} loss={mean_loss:9.3f} "
                f"t={time.time() - t0:6.1f}s")
    # Return the best-eval snapshot (standard DQN model selection; the
    # curve still records the full trajectory for Fig 9).
    return best_params, curve


def save_weights(params, path: str) -> None:
    """JSON weight dump shared with rust/src/qnet/params.rs."""
    payload = {
        "format": "dgro-qnet-v1",
        "embed_dim": model.EMBED_DIM,
        "hidden_dim": model.HIDDEN_DIM,
        "n_iters": model.N_ITERS,
        "params": {
            name: {
                "shape": list(params[name].shape),
                "data": [float(x) for x in np.asarray(params[name]).ravel()],
            }
            for name in model.PARAM_ORDER
        },
    }
    with open(path, "w") as f:
        json.dump(payload, f)


def load_weights(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    assert payload["format"] == "dgro-qnet-v1"
    return {
        name: jnp.asarray(
            np.array(entry["data"], dtype=np.float32).reshape(entry["shape"]))
        for name, entry in payload["params"].items()
    }


def save_curve(curve, path: str) -> None:
    with open(path, "w", newline="") as f:
        wr = csv.writer(f)
        wr.writerow(["episode", "epsilon", "train_diameter",
                     "test_diameter", "td_loss"])
        wr.writerows(curve)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=20)
    ap.add_argument("--episodes", type=int, default=400)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--weights-out", default="../artifacts/qnet_weights.json")
    ap.add_argument("--curve-out", default="../artifacts/training_curve.csv")
    args = ap.parse_args()
    params, curve = train(n=args.n, episodes=args.episodes, seed=args.seed)
    save_weights(params, args.weights_out)
    save_curve(curve, args.curve_out)
    print(f"wrote {args.weights_out} and {args.curve_out}")


if __name__ == "__main__":
    main()
