"""AOT export: lower the DGRO Q-network to HLO text for the Rust runtime.

This is the only bridge between the Python build path and the Rust request
path. For each size bucket N in BUCKETS it lowers

    qnet(theta1..theta10, W, A, deg, vcur) -> (q,)

with the *Pallas* kernels inlined (interpret=True lowers them to plain HLO
ops) and writes ``artifacts/qnet_{N}.hlo.txt``. Weights are parameters, not
constants, so Rust hot-swaps trained thetas from qnet_weights.json without
re-exporting.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax>=0.5
emits 64-bit instruction ids that xla_extension 0.5.1 (the version behind
the published ``xla`` rust crate) rejects; the text parser reassigns ids.
See /opt/xla-example/README.md.

Also trains (or reuses) the DQN weights and emits meta.json describing the
artifact set; ``make artifacts`` is a no-op when inputs are unchanged.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, train

BUCKETS = (16, 32, 64, 128, 256)


def qnet_for_export(*args):
    """Positional-arg wrapper so the HLO parameter order is canonical:

    params 0..9   = theta1..theta10 (model.PARAM_ORDER)
    param 10      = W      (N, N)
    param 11      = A      (N, N)
    param 12      = deg    (N,)
    param 13      = vcur   (N,)
    param 14      = wscale ()   scalar embedding normalizer N*mean(W)
    param 15      = wmean  ()   scalar head-feature normalizer mean(W)
                    (both computed on the *unpadded* matrix by Rust so
                    bucket padding does not change real nodes' Q-values)
    result        = 1-tuple of (N,) Q-values
    """
    leaves = args[:10]
    W, A, deg, vcur = args[10], args[11], args[12], args[13]
    wscale, wmean = args[14], args[15]
    params = model.unflatten_params(leaves)
    q = model.qnet_forward(params, W, A, deg, vcur, wscale, wmean,
                           use_pallas=True)
    return (q,)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def export_bucket(n: int, out_path: str) -> int:
    """Lower the N-bucket Q-net and write HLO text; returns #chars."""
    p, h = model.EMBED_DIM, model.HIDDEN_DIM
    shapes = model.param_shapes(p, h)
    specs = [jax.ShapeDtypeStruct(shapes[name], jnp.float32)
             for name in model.PARAM_ORDER]
    specs += [
        jax.ShapeDtypeStruct((n, n), jnp.float32),  # W
        jax.ShapeDtypeStruct((n, n), jnp.float32),  # A
        jax.ShapeDtypeStruct((n,), jnp.float32),    # deg
        jax.ShapeDtypeStruct((n,), jnp.float32),    # vcur
        jax.ShapeDtypeStruct((), jnp.float32),      # wscale
        jax.ShapeDtypeStruct((), jnp.float32),      # wmean
    ]
    lowered = jax.jit(qnet_for_export).lower(*specs)
    text = to_hlo_text(lowered)
    with open(out_path, "w") as f:
        f.write(text)
    return len(text)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--episodes", type=int,
                    default=int(os.environ.get("DGRO_TRAIN_EPISODES", "400")))
    ap.add_argument("--train-n", type=int,
                    default=int(os.environ.get("DGRO_TRAIN_N", "20")))
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--skip-train", action="store_true",
                    help="reuse an existing qnet_weights.json")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    weights_path = os.path.join(args.out_dir, "qnet_weights.json")
    curve_path = os.path.join(args.out_dir, "training_curve.csv")

    if args.skip_train and os.path.exists(weights_path):
        print(f"reusing {weights_path}")
    else:
        print(f"training DQN: N={args.train_n} episodes={args.episodes}")
        params, curve = train.train(
            n=args.train_n, episodes=args.episodes, seed=args.seed)
        train.save_weights(params, weights_path)
        train.save_curve(curve, curve_path)
        print(f"wrote {weights_path}")

    meta = {
        "format": "dgro-artifacts-v1",
        "embed_dim": model.EMBED_DIM,
        "hidden_dim": model.HIDDEN_DIM,
        "n_iters": model.N_ITERS,
        "param_order": list(model.PARAM_ORDER),
        "buckets": list(BUCKETS),
        "hlo": {},
    }
    for n in BUCKETS:
        out_path = os.path.join(args.out_dir, f"qnet_{n}.hlo.txt")
        size = export_bucket(n, out_path)
        meta["hlo"][str(n)] = os.path.basename(out_path)
        print(f"exported N={n}: {size} chars -> {out_path}")
    with open(os.path.join(args.out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print("artifacts complete")


if __name__ == "__main__":
    main()
