"""Weighted-graph diameter utilities for the DQN reward (build-time only).

The trainer needs D(G_t) after every edge addition (paper SIV-C: reward
r = D(G_t) - D(G_{t+1}) - alpha * w). During ring construction G_t is a
growing path, so full Floyd-Warshall every step would be O(N^3) per step;
instead we keep the pairwise-distance matrix and apply the standard
single-edge relaxation update, O(N^2) per added edge.

The paper defines D over the *largest connected component* when G_t is
disconnected; unreached pairs are +inf in the distance matrix and are
simply excluded from the max.
"""

from __future__ import annotations

import numpy as np

INF = np.float64(np.inf)


def fresh_dist(n: int) -> np.ndarray:
    """All-pairs distance matrix of the empty graph: inf off-diag, 0 diag."""
    d = np.full((n, n), INF, dtype=np.float64)
    np.fill_diagonal(d, 0.0)
    return d


def add_edge(dist: np.ndarray, u: int, v: int, w: float) -> None:
    """Relax every pair through the new undirected edge (u, v, w) in place.

    After the update, dist is again the exact APSP matrix of the graph with
    the edge added: d'(i,j) = min(d(i,j), d(i,u)+w+d(v,j), d(i,v)+w+d(u,j)).
    """
    if w >= dist[u, v]:
        return
    du = dist[:, u].copy()
    dv = dist[:, v].copy()
    via_uv = du[:, None] + (w + dv[None, :])   # i -> u -> v -> j
    via_vu = dv[:, None] + (w + du[None, :])   # i -> v -> u -> j
    np.minimum(dist, via_uv, out=dist)
    np.minimum(dist, via_vu, out=dist)


def largest_cc_diameter(dist: np.ndarray) -> float:
    """Diameter of the largest connected component given APSP ``dist``.

    Components are the equivalence classes of finite distance. Returns 0.0
    for an edgeless graph (every component is a singleton).
    """
    n = dist.shape[0]
    seen = np.zeros(n, dtype=bool)
    best_size = 0
    best_diam = 0.0
    for s in range(n):
        if seen[s]:
            continue
        members = np.isfinite(dist[s])
        seen |= members
        size = int(members.sum())
        if size < best_size:
            continue
        sub = dist[np.ix_(members, members)]
        diam = float(sub.max()) if size > 1 else 0.0
        if size > best_size or (size == best_size and diam > best_diam):
            best_size = size
            best_diam = diam
    return best_diam


def floyd_warshall(weights: np.ndarray, adj: np.ndarray) -> np.ndarray:
    """Reference APSP via Floyd-Warshall (tests only; O(N^3)).

    ``adj`` is a 0/1 mask selecting which entries of ``weights`` are edges.
    """
    n = weights.shape[0]
    d = fresh_dist(n)
    m = adj > 0
    d[m] = weights[m]
    np.fill_diagonal(d, 0.0)
    for k in range(n):
        np.minimum(d, d[:, k][:, None] + d[k][None, :], out=d)
    return d
