"""Pallas kernel: one DGRO graph-embedding iteration (paper Eqn 2 / Fig 4).

The paper's Figure 4 reformulates the structure2vec update as dense matrix
products so it maps onto a systolic matmul unit:

  row 1:  theta2-term  = (A @ mu) @ theta2^T          -- neighbour aggregate
  row 2:  theta3-term  = R @ theta3^T,
          R[v] = sum_u relu(W[v, u] * theta4)         -- latency aggregate

This kernel fuses both rows plus the degree term and the outer relu into a
single pass so ``mu`` stays resident in VMEM across the whole iteration.

TPU mapping (see DESIGN.md "Hardware adaptation"):
  * grid over row-tiles of size ``block_n``; each program instance owns a
    (block_n, N) strip of A and W and produces a (block_n, p) strip of mu'.
  * ``mu`` (N, p) is broadcast to every instance -- at p = 16 padded to the
    128-lane MXU tile it is a few KiB and fits VMEM trivially.
  * A_tile @ mu is the MXU-shaped contraction; the relu-gated latency
    reduction is VPU work expressed as a broadcast-multiply + row reduce.

On this image Pallas runs with ``interpret=True`` (CPU PJRT cannot execute
Mosaic custom-calls); interpret mode lowers to plain HLO, which is exactly
what the AOT path in ``aot.py`` serializes for the Rust runtime.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _latency_agg_kernel(w_ref, t4_ref, out_ref):
    """R[v] = sum_u relu(W[v, u] * t4) for one row strip (VPU work:
    broadcast-multiply + relu + row reduce)."""
    w = w_ref[...]
    t4 = t4_ref[...]
    out_ref[...] = jnp.maximum(
        w[:, :, None] * t4[None, None, :], 0.0).sum(axis=1)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def latency_agg(W, theta4, *, block_n=None, interpret=True):
    """Pallas version of ``ref.latency_term_ref`` — the Eqn-2 latency
    aggregate. Depends only on (W, theta4), so the L2 model computes it
    ONCE per forward and feeds it to every embedding iteration instead
    of recomputing the O(N^2 p) reduction T times (EXPERIMENTS.md §Perf,
    L2 iteration 1)."""
    n = W.shape[0]
    p = theta4.shape[0]
    if block_n is None:
        block_n = min(n, 128)
    if n % block_n != 0:
        raise ValueError(f"block_n={block_n} must divide N={n}")
    return pl.pallas_call(
        _latency_agg_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, n), lambda i: (i, 0)),   # W strip
            pl.BlockSpec(theta4.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n, p), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, p), jnp.float32),
        interpret=interpret,
    )(W, theta4)


def _embed_kernel(a_ref, lat_ref, mu_ref, deg_ref,
                  t1_ref, t2_ref, t3_ref, out_ref):
    """One row-strip of Eqn (2). Shapes inside the kernel:

      a_ref   (bn, N)  strip of the partial-solution adjacency
      lat_ref (bn, p)  strip of the precomputed latency aggregate
      mu_ref  (N, p)   full current embeddings (VMEM-resident)
      deg_ref (bn,)    strip of the degree feature
      t*_ref           embedding parameters theta1..theta3
      out_ref (bn, p)  strip of the next embeddings
    """
    a = a_ref[...]
    lat = lat_ref[...]
    mu = mu_ref[...]
    deg = deg_ref[...]
    t1 = t1_ref[...]
    t2 = t2_ref[...]
    t3 = t3_ref[...]

    # MXU contraction: neighbour aggregate for this row strip.
    neigh = jnp.dot(a, mu, preferred_element_type=jnp.float32)      # (bn, p)
    pre = (
        deg[:, None] * t1[None, :]
        + jnp.dot(neigh, t2.T, preferred_element_type=jnp.float32)
        + jnp.dot(lat, t3.T, preferred_element_type=jnp.float32)
    )
    out_ref[...] = jnp.maximum(pre, 0.0)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def embed_iter_pre(A, lat, mu, deg, theta1, theta2, theta3,
                   *, block_n=None, interpret=True):
    """Pallas-tiled version of ``ref.embed_iter_pre_ref`` (latency
    aggregate precomputed by ``latency_agg``).

    Args:
      A: (N, N) float32 partial-solution adjacency.
      lat: (N, p) float32 from ``latency_agg(W, theta4)``.
      mu: (N, p) float32 current embeddings.
      deg: (N,) float32 degree feature.
      theta1..theta3: Eqn (2) parameters, shapes (p,), (p,p), (p,p).
      block_n: row-tile size; must divide N. Defaults to min(N, 128) --
        128 rows keeps the A-strip at N=256 under 128 KiB of VMEM while
        filling the MXU sublane dimension.
      interpret: run in Pallas interpret mode (required on CPU PJRT).

    Returns:
      (N, p) next embeddings, bit-compatible with the jnp oracle.
    """
    n, p = mu.shape
    if block_n is None:
        block_n = min(n, 128)
    if n % block_n != 0:
        raise ValueError(f"block_n={block_n} must divide N={n}")
    grid = (n // block_n,)
    return pl.pallas_call(
        _embed_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, n), lambda i: (i, 0)),   # A strip
            pl.BlockSpec((block_n, p), lambda i: (i, 0)),   # lat strip
            pl.BlockSpec((n, p), lambda i: (0, 0)),         # mu (broadcast)
            pl.BlockSpec((block_n,), lambda i: (i,)),       # deg strip
            pl.BlockSpec(theta1.shape, lambda i: (0,)),
            pl.BlockSpec(theta2.shape, lambda i: (0, 0)),
            pl.BlockSpec(theta3.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, p), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, p), jnp.float32),
        interpret=interpret,
    )(A, lat, mu, deg, theta1, theta2, theta3)


def embed_iter(A, W, mu, deg, theta1, theta2, theta3, theta4,
               *, block_n=None, interpret=True):
    """Self-contained Eqn-2 iteration (latency aggregate included) —
    kept as the kernel-level unit under test vs ``ref.embed_iter_ref``.
    The L2 model uses ``latency_agg`` + ``embed_iter_pre`` to hoist the
    aggregate out of the T-iteration loop."""
    lat = latency_agg(W, theta4, block_n=block_n, interpret=interpret)
    return embed_iter_pre(A, lat, mu, deg, theta1, theta2, theta3,
                          block_n=block_n, interpret=interpret)
