"""Pallas kernel: DGRO Q-head scoring all candidate edges (paper Eqns 3-4).

Given the final embeddings ``mu`` after T structure2vec iterations, this
kernel scores every candidate edge (v_t -> u) in one shot:

  x_u = [ w(v_t, u), theta5 @ sum_v mu_v, theta6 @ mu_{v_t}, theta7 @ mu_u ]
  Q_u = theta10^T relu(theta9 relu(theta8 relu(x_u)))

Batching all N candidates turns the per-edge MLP into three (N, .) matmuls,
which is what keeps Algorithm 1's inner loop off the scalar unit. The two
state-global features (theta5 @ sum mu, theta6 @ mu_{v_t}) are computed once
per program instance and fused into the first MLP layer instead of being
materialized as broadcast columns:

  relu(x) @ theta8^T
    = relu(w)     * theta8[:, 0]
    + relu(gsum)  @ theta8[:, 1:p+1]^T      (candidate-independent)
    + relu(gcur)  @ theta8[:, p+1:2p+1]^T   (candidate-independent)
    + relu(mu @ theta7^T) @ theta8[:, 2p+1:]^T

so the candidate-independent pieces are rank-1 updates hoisted out of the
(N, 3p+1) concat. This saves materializing x entirely -- see DESIGN.md
S7 (L1 structural optimization).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _qhead_kernel(mu_ref, wrow_ref, gsum_ref, gcur_ref,
                  t7_ref, t8_ref, t9_ref, t10_ref, out_ref, *, p):
    """One candidate-strip of the Q-head.

      mu_ref   (bn, p)  candidate embeddings strip
      wrow_ref (bn,)    W[v_t, u] strip
      gsum_ref (p,)     theta5 @ sum_v mu_v   (precomputed, state-global)
      gcur_ref (p,)     theta6 @ mu_{v_t}     (precomputed, state-global)
      t8 (h, 3p+1), t9 (h, h), t10 (h,)
      out_ref  (bn,)    Q-values strip
    """
    mu = mu_ref[...]
    wrow = wrow_ref[...]
    gsum = gsum_ref[...]
    gcur = gcur_ref[...]
    t7 = t7_ref[...]
    t8 = t8_ref[...]
    t9 = t9_ref[...]
    t10 = t10_ref[...]

    g_cand = jnp.dot(mu, t7.T, preferred_element_type=jnp.float32)  # (bn, p)

    # relu(x) @ t8^T with x = [wrow, gsum, gcur, g_cand], gsum/gcur hoisted.
    w_col = t8[:, 0]                       # (h,)
    t8_sum = t8[:, 1:p + 1]                # (h, p)
    t8_cur = t8[:, p + 1:2 * p + 1]        # (h, p)
    t8_cand = t8[:, 2 * p + 1:]            # (h, p)

    const = t8_sum @ jnp.maximum(gsum, 0.0) + t8_cur @ jnp.maximum(gcur, 0.0)
    pre1 = (
        jnp.maximum(wrow, 0.0)[:, None] * w_col[None, :]
        + jnp.dot(jnp.maximum(g_cand, 0.0), t8_cand.T,
                  preferred_element_type=jnp.float32)
        + const[None, :]
    )                                       # (bn, h)
    h1 = jnp.maximum(pre1, 0.0)
    h2 = jnp.maximum(
        jnp.dot(h1, t9.T, preferred_element_type=jnp.float32), 0.0)
    out_ref[...] = h2 @ t10


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def qhead(mu, wrow, vcur, theta5, theta6, theta7, theta8, theta9, theta10,
          *, block_n=None, interpret=True):
    """Pallas-tiled version of ``ref.qhead_ref``.

    Args:
      mu:   (N, p) final embeddings.
      wrow: (N,)   W[v_t] latency row of the cursor node.
      vcur: (N,)   one-hot cursor (used for mu_{v_t}).
      theta5..theta10: head parameters (see ref.py for shapes).
      block_n: candidate-tile size, must divide N (default min(N, 128)).
      interpret: Pallas interpret mode (required on CPU PJRT).

    Returns:
      (N,) Q-values, numerically identical to the oracle.
    """
    n, p = mu.shape
    if block_n is None:
        block_n = min(n, 128)
    if n % block_n != 0:
        raise ValueError(f"block_n={block_n} must divide N={n}")

    # State-global features: one matvec each, shared by every tile.
    musum = mu.sum(axis=0)
    muv = vcur @ mu
    gsum = theta5 @ musum
    gcur = theta6 @ muv

    grid = (n // block_n,)
    kernel = functools.partial(_qhead_kernel, p=p)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, p), lambda i: (i, 0)),   # mu strip
            pl.BlockSpec((block_n,), lambda i: (i,)),       # wrow strip
            pl.BlockSpec(gsum.shape, lambda i: (0,)),
            pl.BlockSpec(gcur.shape, lambda i: (0,)),
            pl.BlockSpec(theta7.shape, lambda i: (0, 0)),
            pl.BlockSpec(theta8.shape, lambda i: (0, 0)),
            pl.BlockSpec(theta9.shape, lambda i: (0, 0)),
            pl.BlockSpec(theta10.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(mu, wrow, gsum, gcur, theta7, theta8, theta9, theta10)
