"""DGRO Pallas kernels (L1) and their pure-jnp oracle.

``embed.embed_iter`` / ``qhead.qhead`` are the Pallas implementations;
``ref`` holds the ground-truth jnp versions pytest checks them against.
"""

from . import embed, qhead, ref  # noqa: F401

embed_iter = embed.embed_iter
qhead_all = qhead.qhead
