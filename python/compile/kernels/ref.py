"""Pure-jnp reference oracle for the DGRO Q-network kernels.

This module is the correctness ground truth for the Pallas kernels in
``embed.py`` and ``qhead.py``. Every function here is written in plain
``jax.numpy`` with no Pallas constructs, mirroring Eqns (2)-(4) of the DGRO
paper (Wu et al., 2024):

  Eqn (2)  mu_v' = relu( theta1 * x_v
                       + theta2 @ sum_{u in N(v)} mu_u
                       + theta3 @ sum_u relu(theta4 * w(v, u)) )

  Eqn (3)  x_u = [ w(v_t, u),
                   theta5 @ sum_v mu_v,
                   theta6 @ mu_{v_t},
                   theta7 @ mu_u ]            in R^{3p+1}

  Eqn (4)  Q(S_t, u) = theta10^T relu(theta9 relu(theta8 relu(x_u)))

Conventions (shared with the Pallas kernels and the Rust-native mirror in
``rust/src/qnet/native.rs`` -- any change here must be mirrored there):

  * ``A``   -- (N, N) float32 adjacency of the partial solution G_t
               (symmetric 0/1; weighted variants also work).
  * ``W``   -- (N, N) float32 latency matrix of the complete graph G.
  * ``deg`` -- (N,)  float32 degree of each node in G_t (the x_v feature).
  * ``mu``  -- (N, p) float32 node embeddings.
  * ``vcur``-- (N,)  float32 one-hot of the construction cursor v_t.
  * theta1 (p,), theta2 (p, p), theta3 (p, p), theta4 (p,),
    theta5 (p, p), theta6 (p, p), theta7 (p, p),
    theta8 (h, 3p+1), theta9 (h, h), theta10 (h,).

All matvecs are expressed as ``X @ theta.T`` so a whole (N, p) batch of
nodes is one matmul -- exactly the formulation of the paper's Figure 4.
"""

from __future__ import annotations

import jax.numpy as jnp


def relu(x):
    """Elementwise max(x, 0) used throughout Eqns (2)-(4)."""
    return jnp.maximum(x, 0.0)


def latency_term_ref(W, theta4):
    """R[v] = sum_u relu(W[v, u] * theta4)  -- the Eqn (2) third term.

    Args:
      W: (N, N) latency matrix.
      theta4: (p,) per-feature latency scale.

    Returns:
      (N, p) array; row v is the relu-gated latency aggregate for node v.
    """
    # (N, N, 1) * (p,) -> (N, N, p) -> sum over u -> (N, p)
    return relu(W[:, :, None] * theta4[None, None, :]).sum(axis=1)


def embed_iter_pre_ref(A, lat, mu, deg, theta1, theta2, theta3):
    """One structure2vec iteration of Eqn (2) with the latency aggregate
    ``lat = latency_term_ref(W, theta4)`` precomputed. ``lat`` depends
    only on (W, theta4), so callers hoist it out of the T-iteration loop
    (EXPERIMENTS.md §Perf, L2 iteration 1)."""
    neigh = A @ mu                       # (N, p): sum of neighbour embeddings
    pre = (
        deg[:, None] * theta1[None, :]   # theta1 * x_v
        + neigh @ theta2.T               # theta2 @ sum mu_u
        + lat @ theta3.T                 # theta3 @ sum relu(theta4 w)
    )
    return relu(pre)


def embed_iter_ref(A, W, mu, deg, theta1, theta2, theta3, theta4):
    """One structure2vec iteration of Eqn (2) over every node at once
    (self-contained form; recomputes the latency aggregate).

    Returns the next (N, p) embedding matrix.
    """
    lat = latency_term_ref(W, theta4)    # (N, p)
    return embed_iter_pre_ref(A, lat, mu, deg, theta1, theta2, theta3)


def qhead_ref(mu, wrow, vcur, theta5, theta6, theta7, theta8, theta9, theta10):
    """Q-scores of *all* N candidate edges (v_t -> u) at once (Eqns 3-4).

    Args:
      mu:   (N, p) final embeddings after T iterations.
      wrow: (N,)   latency from the cursor v_t to each candidate, W[v_t].
      vcur: (N,)   one-hot of v_t.

    Returns:
      (N,) Q-values; the caller masks visited nodes before argmax.
    """
    musum = mu.sum(axis=0)               # (p,)  sum_v mu_v
    muv = vcur @ mu                      # (p,)  mu_{v_t}
    g_sum = theta5 @ musum               # (p,)
    g_cur = theta6 @ muv                 # (p,)
    g_cand = mu @ theta7.T               # (N, p)  theta7 @ mu_u for all u
    n = mu.shape[0]
    x = jnp.concatenate(
        [
            wrow[:, None],                        # (N, 1)
            jnp.broadcast_to(g_sum, (n, g_sum.shape[0])),
            jnp.broadcast_to(g_cur, (n, g_cur.shape[0])),
            g_cand,
        ],
        axis=1,
    )                                    # (N, 3p+1)
    h1 = relu(relu(x) @ theta8.T)        # (N, h)
    h2 = relu(h1 @ theta9.T)             # (N, h)
    return h2 @ theta10                  # (N,)


def qnet_forward_ref(params, W, A, deg, vcur, wscale=None, wmean=None,
                     n_iters=3):
    """Full Q-network forward: T embedding iterations + head.

    ``params`` is the dict produced by ``model.init_params``. Returns (N,)
    Q-values. This is the oracle for both the Pallas path and the AOT HLO.

    Includes the same scale normalization as ``model.qnet_forward``
    (W' = W / (N * mean(W))): positive scaling commutes with the Eqn (2)
    relu gate, keeps the sum-over-N aggregate O(1) per bucket, and makes
    the net transferable across latency distributions.
    """
    n = W.shape[0]
    p = params["t1"].shape[0]
    if wscale is None:
        wscale = jnp.float32(n) * jnp.mean(W) + jnp.float32(1e-8)
    if wmean is None:
        wmean = jnp.mean(W) + jnp.float32(1e-8)
    wrow = (vcur @ W) / wmean
    W = W / wscale
    mu = jnp.zeros((n, p), dtype=W.dtype)
    for _ in range(n_iters):
        mu = embed_iter_ref(
            A, W, mu, deg,
            params["t1"], params["t2"], params["t3"], params["t4"],
        )
    return qhead_ref(
        mu, wrow, vcur,
        params["t5"], params["t6"], params["t7"],
        params["t8"], params["t9"], params["t10"],
    )
