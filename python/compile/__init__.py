"""DGRO compile path (build-time only; never imported at runtime).

L2 model + DQN training + AOT export. See ../../DESIGN.md.
"""
