"""L2: the DGRO Q-network as a JAX model (paper SIV, Eqns 2-4).

Build-time only. Two interchangeable forward paths:

  * ``qnet_forward(params, ..., use_pallas=True)``  -- composes the L1
    Pallas kernels (interpret mode). This is the path ``aot.py`` lowers to
    HLO for the Rust runtime, so the kernels end up inside the artifact.
  * ``use_pallas=False`` -- composes the jnp oracle from ``kernels.ref``;
    faster to trace, used by the DQN training loop.

pytest asserts the two paths agree to float32 tolerance for every size
bucket, which is the core L1 correctness signal.

Parameter pytree (all float32):
  t1 (p,), t2 (p,p), t3 (p,p), t4 (p,)        -- embedding, Eqn 2
  t5 (p,p), t6 (p,p), t7 (p,p)                -- head features, Eqn 3
  t8 (h, 3p+1), t9 (h,h), t10 (h,)            -- head MLP, Eqn 4
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import embed, qhead, ref

EMBED_DIM = 16     # p -- paper SVII-B1 uses feature dimension 16
HIDDEN_DIM = 32    # h -- head MLP width
N_ITERS = 3        # T -- structure2vec iterations

PARAM_ORDER = ("t1", "t2", "t3", "t4", "t5", "t6", "t7", "t8", "t9", "t10")


def param_shapes(p: int = EMBED_DIM, h: int = HIDDEN_DIM) -> dict:
    """Canonical shapes for every theta, keyed by PARAM_ORDER name."""
    return {
        "t1": (p,),
        "t2": (p, p),
        "t3": (p, p),
        "t4": (p,),
        "t5": (p, p),
        "t6": (p, p),
        "t7": (p, p),
        "t8": (h, 3 * p + 1),
        "t9": (h, h),
        "t10": (h,),
    }


def init_params(key, p: int = EMBED_DIM, h: int = HIDDEN_DIM) -> dict:
    """Glorot-ish init scaled for relu stacks; float32 throughout."""
    shapes = param_shapes(p, h)
    params = {}
    for name in PARAM_ORDER:
        key, sub = jax.random.split(key)
        shape = shapes[name]
        fan_in = shape[-1] if len(shape) > 1 else shape[0]
        scale = jnp.sqrt(2.0 / fan_in)
        params[name] = scale * jax.random.normal(sub, shape, dtype=jnp.float32)
    return params


def flatten_params(params: dict) -> list:
    """Deterministic list-of-arrays view, order shared with Rust."""
    return [params[name] for name in PARAM_ORDER]


def unflatten_params(leaves) -> dict:
    return dict(zip(PARAM_ORDER, leaves))


def default_wscale(W):
    """Canonical latency normalizer: N * mean(W) (so rows of W/scale sum
    to ~1). Computed on the *unpadded* matrix by the Rust caller."""
    n = W.shape[0]
    return jnp.float32(n) * jnp.mean(W) + jnp.float32(1e-8)


def default_wmean(W):
    """Head-feature normalizer: mean(W) (so the Eqn-3 w(v_t, u) feature
    is O(1) — dividing it by N like the embedding normalizer would drown
    the per-candidate signal under the O(1) state features)."""
    return jnp.mean(W) + jnp.float32(1e-8)


def qnet_forward(params, W, A, deg, vcur, wscale=None, wmean=None, *,
                 n_iters: int = N_ITERS, use_pallas: bool = False):
    """Q-values for all N candidates at state S_t = (W, A_t, deg, v_t).

    Args:
      params: the theta pytree.
      W: (N, N) float32 latency matrix of the complete graph.
      A: (N, N) float32 adjacency of the partial solution G_t.
      deg: (N,) float32 degrees in G_t.
      vcur: (N,) float32 one-hot of the cursor node v_t.
      wscale: scalar latency normalizer; defaults to N * mean(W).
        Passed explicitly by the Rust runtime so that a graph padded to a
        size bucket (pad rows of W/A zeroed, pad nodes masked) produces
        *identical* Q-values for the real nodes as the unpadded graph —
        padded zeros keep mu_pad = 0 through every iteration, and the
        explicit scale removes the only other N-dependence.
      n_iters: number of embedding iterations T (static).
      use_pallas: choose the Pallas kernels or the jnp oracle.

    Returns:
      (N,) float32 Q-values. Visited-node masking is the caller's job
      (Rust masks with -inf before argmax; the trainer does the same).

    Scale invariance: W is normalized by ``wscale`` (W' = W / (N*mean W)).
    Positive scaling commutes with the relu gate of Eqn (2), so this
    preserves the paper's functional form while (a) keeping the
    sum-over-N latency aggregate O(1) for every size bucket and (b)
    making the trained net transferable across latency distributions
    (Uniform{1..10} at train time, FABRIC/Bitnode millisecond scales at
    deployment). The normalization is part of the exported HLO, so the
    Rust runtime feeds raw latencies plus the scalar.
    """
    n = W.shape[0]
    p = params["t1"].shape[0]
    if wscale is None:
        wscale = default_wscale(W)
    if wmean is None:
        wmean = default_wmean(W)
    # Head feature: w(v_t, u) / mean(W) — O(1) per-candidate signal.
    wrow = (vcur @ W) / wmean
    # Embedding input: W / (N * mean(W)) — O(1) sum-over-N aggregates.
    W = W / wscale
    mu = jnp.zeros((n, p), dtype=jnp.float32)
    # The Eqn-2 latency aggregate depends only on (W, theta4): compute
    # once and reuse across the T iterations (§Perf, L2 iteration 1 —
    # removes (T-1) * O(N^2 p) redundant work from the lowered HLO).
    if use_pallas:
        lat = embed.latency_agg(W, params["t4"])
        for _ in range(n_iters):
            mu = embed.embed_iter_pre(
                A, lat, mu, deg,
                params["t1"], params["t2"], params["t3"])
        return qhead.qhead(
            mu, wrow, vcur,
            params["t5"], params["t6"], params["t7"],
            params["t8"], params["t9"], params["t10"])
    lat = ref.latency_term_ref(W, params["t4"])
    for _ in range(n_iters):
        mu = ref.embed_iter_pre_ref(
            A, lat, mu, deg,
            params["t1"], params["t2"], params["t3"])
    return ref.qhead_ref(
        mu, wrow, vcur,
        params["t5"], params["t6"], params["t7"],
        params["t8"], params["t9"], params["t10"])


# ---------------------------------------------------------------------------
# DQN loss / SGD step (Algorithm 2).
# ---------------------------------------------------------------------------

def td_loss(params, target_params, batch, *, gamma: float):
    """1-step TD squared loss over a replay batch (paper Eqn 5).

    ``batch`` is a dict of stacked arrays:
      W (B,N,N), A (B,N,N), deg (B,N), vcur (B,N), action (B,) int32,
      reward (B,), A_next (B,N,N), deg_next (B,N), vcur_next (B,N),
      mask_next (B,N) in {0,1} (1 = selectable), done (B,) in {0,1}.

    Target: y = r + gamma * max_u' Q_target(S', u') over selectable u'.
    """
    def q_all(p_, W, A, deg, vcur):
        return qnet_forward(p_, W, A, deg, vcur)

    q_batch = jax.vmap(lambda W, A, d, v: q_all(params, W, A, d, v))
    qt_batch = jax.vmap(lambda W, A, d, v: q_all(target_params, W, A, d, v))

    q_sa = jnp.take_along_axis(
        q_batch(batch["W"], batch["A"], batch["deg"], batch["vcur"]),
        batch["action"][:, None], axis=1)[:, 0]

    q_next = qt_batch(batch["W"], batch["A_next"],
                      batch["deg_next"], batch["vcur_next"])
    neg = jnp.float32(-1e9)
    q_next = jnp.where(batch["mask_next"] > 0, q_next, neg)
    v_next = jnp.max(q_next, axis=1)
    # If no selectable successor remains, treat the state as terminal.
    any_next = jnp.any(batch["mask_next"] > 0, axis=1)
    v_next = jnp.where(any_next, v_next, 0.0)
    y = batch["reward"] + gamma * (1.0 - batch["done"]) * v_next
    y = jax.lax.stop_gradient(y)
    return jnp.mean((y - q_sa) ** 2)


GRAD_CLIP_NORM = 10.0


def sgd_step(params, target_params, batch, *, lr: float, gamma: float):
    """One SGD step on the TD loss with global-norm gradient clipping
    (TD targets are unbounded early in training; clipping keeps the relu
    stack from diverging). Returns (new_params, loss)."""
    loss, grads = jax.value_and_grad(td_loss)(
        params, target_params, batch, gamma=gamma)
    gnorm = jnp.sqrt(sum(
        jnp.sum(g * g) for g in jax.tree_util.tree_leaves(grads)))
    clip = jnp.minimum(1.0, GRAD_CLIP_NORM / (gnorm + 1e-8))
    new_params = jax.tree_util.tree_map(
        lambda w, g: w - lr * clip * g, params, grads)
    return new_params, loss
